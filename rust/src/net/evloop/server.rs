//! The readiness-driven aggregator: one thread multiplexes every
//! client socket through a [`Poller`], driving the *same*
//! `RoundWindow`/`Party` hooks `tcp::serve_on` drives — which is why
//! an evloop run is bit-identical to a sim/threaded/tcp one.
//!
//! Per-connection state machine
//! ----------------------------
//! Each socket is nonblocking and owns two buffers ([`Conn`]):
//!
//! * **read side** — a [`FrameBuf`](super::conn::FrameBuf) reassembles
//!   length-prefixed frames from whatever byte splits the kernel
//!   delivers; complete frames are handled in arrival order, so
//!   per-sender FIFO (the only ordering the §4 machines rely on)
//!   holds exactly as it does on a blocking socket.
//! * **write side** — a bounded [`OutQueue`](super::conn::OutQueue).
//!   The event loop **never blocks on a write**: frames are enqueued,
//!   opportunistically drained, and the remainder waits for the
//!   socket's next writable event. Writable interest is registered
//!   only while the queue is non-empty (no level-triggered busy-spin),
//!   and a queue past its byte cap is a typed
//!   [`QueueOverflow`](super::conn::QueueOverflow) that marks the
//!   client dropped — backpressure surfaces as dropout, never as the
//!   blocking-write deadlock `net/tcp.rs` documents.
//!
//! A dead socket (EOF, read/write error, garbage frame) is a dropped
//! party, not a server error — identical to the TCP transport, the
//! aggregator's stall probe declares it and recovery proceeds.
//! [`StallClock`] quiescence is wired as the poll timeout: a wait that
//! returns no events is the idle probe.

use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::messages::Msg;
use crate::coordinator::metrics::{PipelineStats, AGGREGATOR};
use crate::coordinator::party::{Note, Outbox, Party, RoundSpec};
use crate::coordinator::window::RoundWindow;
use crate::coordinator::Metrics;

use super::super::frame::Frame;
use super::super::tcp::{self, ServeOutcome};
use super::super::transport::{
    harvest, StallClock, Transport, TransportOutcome, DEFAULT_STALL_CAP, DEFAULT_STALL_TIMEOUT,
    MAX_IDLE_PROBES,
};
use super::super::{Addr, Network};
use super::conn::{Conn, ReadOutcome};
use super::poller::{Interest, Poller, PollerKind};
use super::shard::{self, LoopEvt, ShardLoop, ShardSet};

/// The listening socket's registration token (connection tokens are
/// slab indices, so they never reach this).
const LISTENER_TOKEN: usize = usize::MAX;

/// How long the post-run Stop drain waits for slow clients before
/// giving up (best-effort, like the TCP transport's Stop writes).
const STOP_DRAIN: Duration = Duration::from_secs(5);

/// The multiplexed connection table plus its poller: everything the
/// event loop owns besides the protocol state.
struct EvServer {
    poller: Poller,
    /// Token-indexed slab; closed slots stay `None` (each client
    /// connects exactly once per run, so tokens are never reused).
    conns: Vec<Option<Conn>>,
    /// Client index → live token (None = not yet joined, or dropped).
    client_slot: Vec<Option<usize>>,
    joined: usize,
    live: u64,
    /// Connection-count and per-connection queue-depth meters, merged
    /// into the aggregator's metrics at the end of the run.
    io: Metrics,
}

impl EvServer {
    fn new(poller: Poller, n_clients: usize) -> EvServer {
        EvServer {
            poller,
            conns: Vec::with_capacity(n_clients),
            client_slot: vec![None; n_clients],
            joined: 0,
            live: 0,
            io: Metrics::new(),
        }
    }

    /// Accept until the listener would block, registering each new
    /// socket read-only under a fresh slab token.
    fn accept_ready(&mut self, listener: &TcpListener) -> Result<()> {
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    stream.set_nodelay(true).ok();
                    stream.set_nonblocking(true).context("set_nonblocking")?;
                    let fd = stream.as_raw_fd();
                    let token = self.conns.len();
                    self.poller.register(fd, token, Interest::READ).context("register conn")?;
                    self.conns.push(Some(Conn::new(stream, fd)));
                    self.live += 1;
                    self.io.record_connections(AGGREGATOR, self.live);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("accept"),
            }
        }
    }

    /// Close one connection: deregister, drop the socket, clear the
    /// client mapping (its party is dropped from here on).
    fn close(&mut self, token: usize) {
        if let Some(conn) = self.conns.get_mut(token).and_then(Option::take) {
            let _ = self.poller.deregister(conn.fd);
            if let Some(ci) = conn.client {
                self.client_slot[ci] = None;
            }
            self.live -= 1;
        }
    }

    fn set_interest(&mut self, token: usize, want: Interest) {
        let Some(conn) = self.conns.get_mut(token).and_then(Option::as_mut) else { return };
        if conn.interest != want {
            let fd = conn.fd;
            conn.interest = want;
            if let Err(e) = self.poller.reregister(fd, token, want) {
                eprintln!("serve(evloop): reregister failed ({e}), closing conn {token}");
                self.close(token);
            }
        }
    }

    /// Drain a readable socket, appending complete frames as
    /// `(client, frame)` pairs. Handles the `Hello` handshake inline
    /// (frames before it are a protocol error; frames after it carry
    /// the sender's client index). `joining` turns a lost socket into
    /// a hard error — before the party set is complete there is no
    /// dropout semantics to absorb it.
    fn handle_read(
        &mut self,
        token: usize,
        frames: &mut Vec<(usize, Frame)>,
        joining: bool,
    ) -> Result<()> {
        let Some(conn) = self.conns.get_mut(token).and_then(Option::as_mut) else {
            return Ok(()); // stale event for an already-closed conn
        };
        let mut got = Vec::new();
        let outcome = conn.read_ready(&mut got);
        let buffered = conn.buffered_bytes();
        let mut client = conn.client;
        self.io.record_conn_buffered(AGGREGATOR, buffered as u64);
        for f in got {
            match client {
                Some(ci) => frames.push((ci, f)),
                None => {
                    let Frame::Hello { client: c } = f else {
                        bail!("expected Hello, got {f:?}")
                    };
                    let ci = c as usize;
                    let n = self.client_slot.len();
                    if ci >= n {
                        bail!("client index {ci} out of range (need 0..{n})");
                    }
                    if self.client_slot[ci].is_some() {
                        bail!("client {ci} connected twice");
                    }
                    self.client_slot[ci] = Some(token);
                    if let Some(conn) = self.conns[token].as_mut() {
                        conn.client = Some(ci);
                    }
                    client = Some(ci);
                    self.joined += 1;
                }
            }
        }
        if let ReadOutcome::Closed(why) = outcome {
            if joining {
                bail!("client socket lost during join: {why}");
            }
            // a vanished client is a dropped party, not a server error
            // (tcp parity: Event::Gone) — the stall probe declares it
            let who = client.map(|c| c.to_string()).unwrap_or_else(|| "?".into());
            eprintln!("serve(evloop): client {who} disconnected ({why}), marking dropped");
            self.close(token);
        }
        Ok(())
    }

    /// Drain a connection's outbound queue as far as the socket
    /// accepts, keeping writable interest exactly while bytes remain.
    fn flush(&mut self, token: usize) {
        let Some(conn) = self.conns.get_mut(token).and_then(Option::as_mut) else { return };
        match conn.write_ready() {
            Ok(drained) => {
                let bytes = conn.buffered_bytes();
                self.io.record_conn_buffered(AGGREGATOR, bytes as u64);
                let want = if drained { Interest::READ } else { Interest::BOTH };
                self.set_interest(token, want);
            }
            Err(e) => {
                let who = conn.client.map(|c| c.to_string()).unwrap_or_else(|| "?".into());
                eprintln!("serve(evloop): client {who} write failed ({e}), marking dropped");
                self.close(token);
            }
        }
    }

    /// Enqueue one frame to a client and opportunistically drain it.
    /// Dead or dropped clients are skipped; a queue overflow (typed
    /// [`QueueOverflow`](super::conn::QueueOverflow)) marks the client
    /// dropped — never a blocking wait.
    fn send_to_client(&mut self, ci: usize, frame: &Frame) {
        let Some(token) = self.client_slot[ci] else { return };
        let Some(conn) = self.conns.get_mut(token).and_then(Option::as_mut) else { return };
        if let Err(e) = conn.out.enqueue(frame, token) {
            eprintln!("serve(evloop): client {ci} send failed ({e:#}), marking dropped");
            self.close(token);
            return;
        }
        self.flush(token);
    }

    /// Enqueue pre-encoded `Msg` wire bytes to a client (the
    /// zero-copy sibling of [`send_to_client`]: same slot lookup, same
    /// overflow-marks-dropped handling, but the body bytes go straight
    /// into the out-queue behind a 9-byte frame header instead of
    /// being re-copied through a `Frame::Msg` encode).
    fn send_wire_to_client(&mut self, ci: usize, bytes: Vec<u8>) {
        let Some(token) = self.client_slot[ci] else { return };
        let Some(conn) = self.conns.get_mut(token).and_then(Option::as_mut) else { return };
        if let Err(e) = conn.out.enqueue_msg(bytes, token) {
            eprintln!("serve(evloop): client {ci} send failed ({e:#}), marking dropped");
            self.close(token);
            return;
        }
        self.flush(token);
    }

    /// Route an aggregator outbox: meter + enqueue every message,
    /// feed scheduler-control notes to the window (tcp parity:
    /// aggregator-outbox notes never trigger `on_round_complete`).
    fn route(
        &mut self,
        net: &mut Network,
        ob: Outbox,
        notes: &mut Vec<Note>,
        win: &mut RoundWindow,
    ) -> Result<()> {
        for (to, msg) in ob.msgs {
            let Addr::Client(ci) = to else { bail!("aggregator addressed itself") };
            let bytes = msg.into_bytes();
            net.meter(Addr::Aggregator, to, bytes.len());
            self.send_wire_to_client(ci, bytes);
        }
        for n in ob.notes {
            if let Some(n) = win.observe(n) {
                notes.push(n);
            }
        }
        Ok(())
    }

    /// Best-effort post-run drain: flush every remaining outbound byte
    /// (the Stop frames), closing each connection as its queue empties
    /// so level-triggered EOF readiness from exiting clients cannot
    /// spin the loop.
    fn drain_outbound(&mut self, deadline: Instant) {
        let mut events = Vec::new();
        loop {
            for token in 0..self.conns.len() {
                let Some(conn) = self.conns[token].as_ref() else { continue };
                if conn.out.is_empty() {
                    self.close(token);
                } else {
                    self.set_interest(token, Interest::WRITE);
                }
            }
            if self.live == 0 {
                return;
            }
            let now = Instant::now();
            if now >= deadline {
                return;
            }
            let wait = (deadline - now).min(Duration::from_millis(100));
            if self.poller.wait(&mut events, Some(wait)).is_err() {
                return;
            }
            for i in 0..events.len() {
                let ev = events[i];
                if ev.hangup {
                    self.close(ev.token);
                } else if ev.writable {
                    self.flush(ev.token);
                }
            }
        }
    }
}

/// Host the aggregator on a readiness-driven event loop: accept
/// `n_clients` joins, run the schedule with up to `window` rounds in
/// flight, return the run's notes and byte counters — the evloop
/// sibling of [`tcp::serve`], same protocol semantics, one thread for
/// any number of clients.
pub fn serve(
    listen: &str,
    aggregator: Box<dyn Party + '_>,
    schedule: &[RoundSpec],
    n_clients: usize,
    clock: StallClock,
    window: usize,
    poller: PollerKind,
) -> Result<ServeOutcome> {
    let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
    serve_on(listener, aggregator, schedule, n_clients, clock, window, poller)
}

/// [`serve`] on an already-bound listener (lets tests bind port 0 and
/// learn the real port before clients race to connect).
pub fn serve_on(
    listener: TcpListener,
    mut aggregator: Box<dyn Party + '_>,
    schedule: &[RoundSpec],
    n_clients: usize,
    mut clock: StallClock,
    window: usize,
    poller: PollerKind,
) -> Result<ServeOutcome> {
    if n_clients > u16::MAX as usize {
        bail!("{n_clients} clients exceeds the Hello frame's u16 index space");
    }
    let listen = listener.local_addr().map(|a| a.to_string()).unwrap_or_default();
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let mut srv = EvServer::new(poller.build().context("build poller")?, n_clients);
    srv.poller
        .register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::READ)
        .context("register listener")?;
    eprintln!(
        "serve(evloop/{}): listening on {listen}, waiting for {n_clients} client(s)",
        srv.poller.name()
    );

    // -- join phase: accept and handshake every client. Frames a fast
    // client sends beyond its Hello (none today — clients wait for the
    // first Round — but the protocol does not forbid it) are carried
    // into the protocol loop.
    let mut events = Vec::new();
    let mut frames: Vec<(usize, Frame)> = Vec::new();
    while srv.joined < n_clients {
        srv.poller.wait(&mut events, None).context("poll (join)")?;
        for i in 0..events.len() {
            let ev = events[i];
            if ev.token == LISTENER_TOKEN {
                srv.accept_ready(&listener)?;
            } else {
                srv.handle_read(ev.token, &mut frames, true)?;
            }
        }
    }
    srv.poller.deregister(listener.as_raw_fd()).ok();
    eprintln!("serve(evloop): all {n_clients} client(s) joined");

    // -- protocol loop: the exact driver `tcp::serve_on` runs, with
    // the poll timeout playing the role of `recv_timeout`.
    let mut net = Network::new(n_clients);
    let mut notes: Vec<Note> = Vec::new();
    let mut win = RoundWindow::new(schedule, window);
    let mut idle_probes = 0u32;
    let mut processed_since_probe = 0u64;
    let mut last_event = Instant::now();
    while !win.done() {
        // open every round the window allows, in schedule order: the
        // boundary is enqueued on every socket first, so each client
        // orders the round ahead of its first protocol message. Only
        // the active party (client 0) receives the batch ids (batch-
        // membership leak, as in tcp::serve_on).
        while let Some(spec) = win.next_start() {
            net.phase = spec.phase;
            for ci in 0..n_clients {
                let for_client = if ci == 0 {
                    spec.clone()
                } else {
                    RoundSpec { ids: Vec::new(), ..spec.clone() }
                };
                srv.send_to_client(ci, &Frame::Round(for_client));
            }
            let mut ob = Outbox::default();
            aggregator.on_round_start(spec, &mut ob)?;
            srv.route(&mut net, ob, &mut notes, &mut win)?;
        }
        if frames.is_empty() {
            srv.poller.wait(&mut events, Some(clock.timeout())).context("poll")?;
            if events.is_empty() {
                // quiescent for the stall window: probe the aggregator
                // for dropped parties, but only when truly idle — a
                // timeout right after a burst is not a dropout. The
                // gap anchor resets so stall windows never feed the
                // EWMA (the clock tracks frame cadence, not its own
                // timeouts).
                last_event = Instant::now();
                let mut ob = Outbox::default();
                if processed_since_probe == 0 {
                    aggregator.on_stall(&mut ob)?;
                }
                let acted = !ob.msgs.is_empty() || !ob.notes.is_empty();
                srv.route(&mut net, ob, &mut notes, &mut win)?;
                if acted || processed_since_probe > 0 {
                    idle_probes = 0;
                } else {
                    idle_probes += 1;
                    if idle_probes >= MAX_IDLE_PROBES {
                        bail!(
                            "protocol stalled: round {} never completed",
                            win.oldest_in_flight().unwrap_or(0)
                        );
                    }
                }
                processed_since_probe = 0;
                continue;
            }
            let now = Instant::now();
            clock.observe_gap(now - last_event);
            last_event = now;
            for i in 0..events.len() {
                let ev = events[i];
                if ev.writable {
                    srv.flush(ev.token);
                }
                if ev.readable || ev.hangup {
                    srv.handle_read(ev.token, &mut frames, false)?;
                }
            }
            if srv.live == 0 && frames.is_empty() {
                bail!("all client connections lost");
            }
        }
        // handle every complete frame in arrival order (per-sender
        // FIFO: each conn's frames were appended in read order)
        for (ci, frame) in std::mem::take(&mut frames) {
            match frame {
                Frame::Msg { bytes } => {
                    idle_probes = 0;
                    processed_since_probe += 1;
                    net.meter(Addr::Client(ci), Addr::Aggregator, bytes.len());
                    let msg = Msg::decode(&bytes)?;
                    let mut ob = Outbox::default();
                    aggregator.on_message(Addr::Client(ci), msg, &mut ob)?;
                    srv.route(&mut net, ob, &mut notes, &mut win)?;
                }
                Frame::Note(n) => {
                    idle_probes = 0;
                    processed_since_probe += 1;
                    match n {
                        Note::Failed { who, error } => bail!("party {who} failed: {error}"),
                        n => {
                            if let Some(n) = win.observe(n) {
                                if let Note::RoundDone { round } = &n {
                                    // scheduler bookkeeping for the
                                    // server-side aggregator
                                    aggregator.on_round_complete(*round);
                                }
                                notes.push(n);
                            }
                        }
                    }
                }
                f => bail!("unexpected frame from client {ci}: {f:?}"),
            }
        }
    }
    for ci in 0..n_clients {
        srv.send_to_client(ci, &Frame::Stop);
    }
    srv.drain_outbound(Instant::now() + STOP_DRAIN);
    let mut metrics = aggregator.take_metrics();
    metrics.record_pipeline(win.stats());
    metrics.merge(std::mem::take(&mut srv.io));
    Ok(ServeOutcome { notes, net, metrics })
}

/// Route an aggregator outbox through the shard fabric: meter +
/// enqueue every message (to whichever loop owns the client), feed
/// scheduler-control notes to the window — the sharded sibling of
/// [`EvServer::route`], same metering, same note policy.
fn route_sharded(
    net: &mut Network,
    ob: Outbox,
    notes: &mut Vec<Note>,
    win: &mut RoundWindow,
    shards: &mut ShardSet,
) -> Result<()> {
    for (to, msg) in ob.msgs {
        let Addr::Client(ci) = to else { bail!("aggregator addressed itself") };
        let bytes = msg.into_bytes();
        net.meter(Addr::Aggregator, to, bytes.len());
        shards.send_wire(ci, bytes);
    }
    for n in ob.notes {
        if let Some(n) = win.observe(n) {
            notes.push(n);
        }
    }
    Ok(())
}

/// The sharded driver: join bookkeeping plus the exact protocol loop
/// `serve_on` runs, with the shared [`LoopEvt`] channel playing the
/// role the poller plays there — `recv_timeout(clock.timeout())` is
/// the quiescence probe, a received burst is an event batch.
#[allow(clippy::too_many_arguments)]
fn drive_sharded(
    aggregator: &mut (dyn Party + '_),
    schedule: &[RoundSpec],
    n_clients: usize,
    clock: &mut StallClock,
    window: usize,
    threads: usize,
    shards: &mut ShardSet,
    evt_rx: &Receiver<LoopEvt>,
) -> Result<(Vec<Note>, Network, PipelineStats)> {
    // -- join phase: every socket is already accepted and dealt; wait
    // for each loop to report its clients' Hello handshakes. Frames a
    // fast client sends beyond its Hello are carried into the protocol
    // loop, as in the single-loop server.
    let mut frames: Vec<(usize, Frame)> = Vec::new();
    let mut joined = 0usize;
    let mut live = n_clients as u64;
    while joined < n_clients {
        match evt_rx.recv() {
            Ok(LoopEvt::Joined { loop_id, client }) => {
                if shards.client_loop[client].is_some() {
                    bail!("client {client} connected twice");
                }
                shards.client_loop[client] = Some(loop_id);
                joined += 1;
            }
            Ok(LoopEvt::Frame { client, frame }) => frames.push((client, frame)),
            Ok(LoopEvt::Gone { why, .. }) => bail!("client socket lost during join: {why}"),
            Ok(LoopEvt::Fatal(e)) => return Err(e),
            Err(_) => bail!("event loops exited during join"),
        }
    }
    eprintln!("serve(evloop): all {n_clients} client(s) joined across {threads} loop(s)");

    // -- protocol loop: identical structure and semantics to
    // `serve_on`'s, with channel receives in place of poller waits.
    let mut net = Network::new(n_clients);
    let mut notes: Vec<Note> = Vec::new();
    let mut win = RoundWindow::new(schedule, window);
    let mut idle_probes = 0u32;
    let mut processed_since_probe = 0u64;
    let mut last_event = Instant::now();
    while !win.done() {
        while let Some(spec) = win.next_start() {
            net.phase = spec.phase;
            for ci in 0..n_clients {
                let for_client = if ci == 0 {
                    spec.clone()
                } else {
                    RoundSpec { ids: Vec::new(), ..spec.clone() }
                };
                shards.send_frame(ci, Frame::Round(for_client));
            }
            let mut ob = Outbox::default();
            aggregator.on_round_start(spec, &mut ob)?;
            route_sharded(&mut net, ob, &mut notes, &mut win, shards)?;
        }
        shards.wake();
        if frames.is_empty() {
            match evt_rx.recv_timeout(clock.timeout()) {
                Err(RecvTimeoutError::Timeout) => {
                    // quiescent for the stall window: same probe policy
                    // and gap-anchor reset as the single loop
                    last_event = Instant::now();
                    let mut ob = Outbox::default();
                    if processed_since_probe == 0 {
                        aggregator.on_stall(&mut ob)?;
                    }
                    let acted = !ob.msgs.is_empty() || !ob.notes.is_empty();
                    route_sharded(&mut net, ob, &mut notes, &mut win, shards)?;
                    shards.wake();
                    if acted || processed_since_probe > 0 {
                        idle_probes = 0;
                    } else {
                        idle_probes += 1;
                        if idle_probes >= MAX_IDLE_PROBES {
                            bail!(
                                "protocol stalled: round {} never completed",
                                win.oldest_in_flight().unwrap_or(0)
                            );
                        }
                    }
                    processed_since_probe = 0;
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => bail!("all event loops exited"),
                Ok(first) => {
                    let now = Instant::now();
                    clock.observe_gap(now - last_event);
                    last_event = now;
                    let mut batch = vec![first];
                    while let Ok(e) = evt_rx.try_recv() {
                        batch.push(e);
                    }
                    for e in batch {
                        match e {
                            LoopEvt::Frame { client, frame } => frames.push((client, frame)),
                            LoopEvt::Gone { client, why } => {
                                // a vanished client is a dropped party,
                                // not a server error — the stall probe
                                // declares it (single-loop parity)
                                let who = client
                                    .map(|c| c.to_string())
                                    .unwrap_or_else(|| "?".into());
                                eprintln!(
                                    "serve(evloop): client {who} disconnected ({why}), \
                                     marking dropped"
                                );
                                if let Some(ci) = client {
                                    shards.client_loop[ci] = None;
                                }
                                live -= 1;
                            }
                            LoopEvt::Joined { client, .. } => {
                                bail!("client {client} connected twice")
                            }
                            LoopEvt::Fatal(e) => return Err(e),
                        }
                    }
                }
            }
            if live == 0 && frames.is_empty() {
                bail!("all client connections lost");
            }
        }
        // handle every complete frame in arrival order (per-sender
        // FIFO: one loop owns each conn, and mpsc preserves its order)
        for (ci, frame) in std::mem::take(&mut frames) {
            match frame {
                Frame::Msg { bytes } => {
                    idle_probes = 0;
                    processed_since_probe += 1;
                    net.meter(Addr::Client(ci), Addr::Aggregator, bytes.len());
                    let msg = Msg::decode(&bytes)?;
                    let mut ob = Outbox::default();
                    aggregator.on_message(Addr::Client(ci), msg, &mut ob)?;
                    route_sharded(&mut net, ob, &mut notes, &mut win, shards)?;
                }
                Frame::Note(n) => {
                    idle_probes = 0;
                    processed_since_probe += 1;
                    match n {
                        Note::Failed { who, error } => bail!("party {who} failed: {error}"),
                        n => {
                            if let Some(n) = win.observe(n) {
                                if let Note::RoundDone { round } = &n {
                                    aggregator.on_round_complete(*round);
                                }
                                notes.push(n);
                            }
                        }
                    }
                }
                f => bail!("unexpected frame from client {ci}: {f:?}"),
            }
        }
        shards.wake();
    }
    Ok((notes, net, win.stats()))
}

/// [`serve_on`] across `threads` token-sharded event loops: the driver
/// thread accepts every connection (dealing socket `j` to loop `j % K`
/// — see [`shard`]), K loop threads own disjoint connection slabs with
/// no locks on the read/write path, and protocol events funnel back to
/// this thread's `RoundWindow` driver. `threads <= 1` is exactly
/// [`serve_on`]; any K produces bit-identical reports (per-sender
/// FIFO survives sharding because each connection lives on one loop).
#[allow(clippy::too_many_arguments)]
pub fn serve_sharded(
    listener: TcpListener,
    mut aggregator: Box<dyn Party + '_>,
    schedule: &[RoundSpec],
    n_clients: usize,
    mut clock: StallClock,
    window: usize,
    poller: PollerKind,
    threads: usize,
) -> Result<ServeOutcome> {
    let threads = threads.max(1).min(n_clients.max(1));
    if threads <= 1 {
        return serve_on(listener, aggregator, schedule, n_clients, clock, window, poller);
    }
    if n_clients > u16::MAX as usize {
        bail!("{n_clients} clients exceeds the Hello frame's u16 index space");
    }
    let listen = listener.local_addr().map(|a| a.to_string()).unwrap_or_default();
    // build every poller first so a backend failure is a clean
    // configuration-time error, not a half-spawned fleet
    let mut pollers = Vec::with_capacity(threads);
    for _ in 0..threads {
        pollers.push(poller.build().context("build poller")?);
    }
    eprintln!(
        "serve(evloop/{}): listening on {listen}, {threads} loop shards, waiting for \
         {n_clients} client(s)",
        pollers[0].name()
    );
    // the driver plays acceptor: the connection peak is metered here,
    // where the whole federation is visible (loops each see 1/K of it)
    let mut io = Metrics::new();
    let sockets = shard::accept_shards(&listener, n_clients, threads, &mut io, None)?;
    drop(listener);

    let (evt_tx, evt_rx) = mpsc::channel();
    let mut ctls = Vec::with_capacity(threads);
    let mut wakes = Vec::with_capacity(threads);
    let mut loops = Vec::with_capacity(threads);
    for (l, (poller, socks)) in pollers.into_iter().zip(sockets).enumerate() {
        let (ctl_tx, ctl_rx) = mpsc::channel();
        let (wake_tx, wake_rx) = UnixStream::pair().context("wake pair")?;
        wake_tx.set_nonblocking(true).context("nonblocking wake")?;
        loops.push(ShardLoop::new(l, poller, socks, n_clients, wake_rx, ctl_rx, evt_tx.clone())?);
        ctls.push(ctl_tx);
        wakes.push(wake_tx);
    }
    drop(evt_tx); // loops hold the only senders: hangup = all loops gone

    thread::scope(|s| -> Result<ServeOutcome> {
        // shards lives inside the scope so every exit path drops it
        // (hanging up the loops) before the scope joins their threads
        let mut shards = ShardSet::new(ctls, wakes, n_clients);
        let handles: Vec<_> = loops
            .into_iter()
            .map(|sl| {
                thread::Builder::new()
                    .name(format!("evloop-shard-{}", sl.id()))
                    .spawn_scoped(s, move || sl.run())
                    .expect("spawn evloop shard")
            })
            .collect();
        let served = drive_sharded(
            &mut *aggregator,
            schedule,
            n_clients,
            &mut clock,
            window,
            threads,
            &mut shards,
            &evt_rx,
        );
        if served.is_ok() {
            for ci in 0..n_clients {
                shards.send_frame(ci, Frame::Stop);
            }
            shards.drain_all(STOP_DRAIN);
        }
        shards.wake();
        drop(shards);
        let mut loop_io = Metrics::new();
        for h in handles {
            match h.join() {
                Ok(m) => loop_io.merge(m),
                Err(_) => eprintln!("serve(evloop): a loop shard panicked"),
            }
        }
        let (notes, net, stats) = served?;
        let mut metrics = aggregator.take_metrics();
        metrics.record_pipeline(stats);
        metrics.merge(io);
        metrics.merge(loop_io);
        Ok(ServeOutcome { notes, net, metrics })
    })
}

/// In-process evloop runs: the aggregator multiplexes every client
/// over real localhost sockets on *one* event-loop thread, while each
/// client party runs the ordinary blocking [`tcp`] client loop on its
/// own thread (clients are out of scope for the C10K claim — the
/// aggregator is the bottleneck the event loop exists to remove).
///
/// The fourth [`TransportKind`](crate::coordinator::TransportKind):
/// same party machines, same `RoundWindow` scheduling, bit-identical
/// reports and Table-2 counters to sim/threaded/tcp (asserted by
/// `tests/transport_equivalence.rs` and friends).
pub struct EvloopTransport {
    n_clients: usize,
    stall_floor: Duration,
    stall_cap: Duration,
    poller: PollerKind,
    threads: usize,
}

impl EvloopTransport {
    pub fn new(n_clients: usize) -> Self {
        EvloopTransport {
            n_clients,
            stall_floor: DEFAULT_STALL_TIMEOUT,
            stall_cap: DEFAULT_STALL_CAP,
            poller: PollerKind::Auto,
            threads: 1,
        }
    }

    /// Override the dropout-detection floor (reachable from
    /// `RunConfig::stall_timeout_ms`).
    pub fn with_stall_timeout(mut self, stall_timeout: Duration) -> Self {
        self.stall_floor = stall_timeout;
        self
    }

    /// Override the adaptive window's cap (reachable from
    /// `RunConfig::stall_cap_ms`).
    pub fn with_stall_cap(mut self, cap: Duration) -> Self {
        self.stall_cap = cap;
        self
    }

    /// Force a poller backend (tests pin the `poll(2)` fallback
    /// without the `VFL_EVLOOP_POLLER` env race).
    pub fn with_poller(mut self, kind: PollerKind) -> Self {
        self.poller = kind;
        self
    }

    /// Run the aggregator across `threads` token-sharded event loops
    /// (reachable from `RunConfig::evloop_threads`; `--evloop-threads`).
    /// 1 = today's single loop, byte-identical; any K produces
    /// bit-identical reports (see [`serve_sharded`]).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }
}

impl Transport for EvloopTransport {
    fn execute<'e>(
        &mut self,
        parties: Vec<Box<dyn Party + 'e>>,
        schedule: &[RoundSpec],
        window: usize,
    ) -> Result<TransportOutcome> {
        assert_eq!(parties.len(), self.n_clients + 1, "aggregator + clients");
        // same boundary check as the threaded transport: client
        // parties run on sibling threads here
        if parties.iter().any(|p| !p.concurrent_safe()) {
            bail!(
                "the evloop transport requires the reference backend \
                 (a shared PJRT engine is not audited for concurrent use)"
            );
        }
        let listener = TcpListener::bind("127.0.0.1:0").context("bind localhost")?;
        let addr = listener.local_addr().context("local addr")?.to_string();
        let mut parties = parties;
        let aggregator = parties.remove(0);
        let clock = StallClock::new(self.stall_floor, self.stall_cap);
        let (n_clients, kind, threads) = (self.n_clients, self.poller, self.threads);

        thread::scope(|s| -> Result<TransportOutcome> {
            let mut handles = Vec::with_capacity(parties.len());
            for (ci, mut party) in parties.into_iter().enumerate() {
                let addr = addr.clone();
                handles.push(s.spawn(move || {
                    let r = tcp::join_addr(&addr, ci, &mut *party);
                    (party, r)
                }));
            }
            let served =
                serve_sharded(listener, aggregator, schedule, n_clients, clock, window, kind, threads);
            // join the client threads either way: a server error drops
            // its sockets, which unblocks every client read with EOF
            let mut clients: Vec<Box<dyn Party + 'e>> = Vec::with_capacity(handles.len());
            let mut client_err: Option<anyhow::Error> = None;
            for h in handles {
                match h.join() {
                    Ok((party, r)) => {
                        clients.push(party);
                        if let Err(e) = r {
                            client_err.get_or_insert(e);
                        }
                    }
                    Err(_) => {
                        client_err.get_or_insert_with(|| anyhow!("client thread panicked"));
                    }
                }
            }
            let served = served?; // the server error wins
            if let Some(e) = client_err {
                // the server completed, so the protocol did: a late
                // client-side error (e.g. while reading Stop) is worth
                // reporting but not failing a finished run over
                eprintln!("evloop: client-side error after completion: {e:#}");
            }
            // ServeOutcome.metrics already holds the aggregator's
            // meters + pipeline + connection counters; harvest adds
            // the client parties' meters and the final parameters
            harvest(clients, served.notes, served.net, served.metrics)
        })
    }
}
