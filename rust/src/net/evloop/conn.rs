//! Per-connection state for the event loop: buffered partial-frame
//! reassembly on the read side, a bounded outbound byte queue on the
//! write side.
//!
//! The no-blocking-write invariant lives here: the event loop never
//! calls a blocking `write_all`. Outbound frames are encoded into
//! [`OutQueue`] and drained with nonblocking `write` calls whenever
//! the socket reports writable; a queue past its byte cap is a typed
//! [`QueueOverflow`] — backpressure surfaces as an error instead of a
//! deadlock (the exact failure mode the blocking `net/tcp.rs` writer
//! has when both sides stuff their socket buffers).

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::os::unix::io::RawFd;

use anyhow::{bail, Result};

use crate::net::frame::{msg_frame_header, Frame, FrameTooLong, MAX_FRAME_LEN};

use super::poller::Interest;

/// Default per-connection outbound cap: one maximum-size frame plus
/// headroom. A queue this deep means the peer has not drained tens of
/// rounds of traffic — that is a dead or hostile peer, not
/// backpressure worth buffering through.
pub const DEFAULT_OUTBOUND_CAP_BYTES: usize = (MAX_FRAME_LEN as usize) + (4 << 20);

/// Typed error for an outbound queue past its byte cap. The event loop
/// treats the connection as failed (a peer that stops reading is
/// indistinguishable from a dropped one) instead of blocking or
/// buffering unboundedly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueOverflow {
    /// Registration token of the offending connection.
    pub token: usize,
    /// Bytes queued after the rejected enqueue would have applied.
    pub queued: usize,
    /// The enforced cap.
    pub cap: usize,
}

impl std::fmt::Display for QueueOverflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "outbound queue overflow on conn {}: {} bytes queued exceeds the {}-byte cap",
            self.token, self.queued, self.cap
        )
    }
}

impl std::error::Error for QueueOverflow {}

/// Incremental frame reassembly: bytes arrive in arbitrary splits
/// (nonblocking reads return whatever the kernel has), frames leave
/// whole. A cursor-compacted `Vec` instead of a ring: frames are
/// consumed front-to-back, and compaction is amortized by only
/// memmoving once the dead prefix passes 64 KiB.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
    start: usize,
}

/// Compact once this many consumed bytes sit before the cursor.
const COMPACT_AT: usize = 64 << 10;

impl FrameBuf {
    /// Append freshly-read bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Unconsumed bytes currently buffered (the read-side component of
    /// the per-connection memory meter).
    pub fn len(&self) -> usize {
        self.buf.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pop one complete frame if the buffer holds one. A length prefix
    /// past [`MAX_FRAME_LEN`] is the same typed [`FrameTooLong`] error
    /// the blocking reader raises, rejected before any allocation.
    pub fn next_frame(&mut self) -> Result<Option<Frame>> {
        let avail = &self.buf[self.start..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]);
        if len > MAX_FRAME_LEN {
            bail!(FrameTooLong { len: len as u64, max: MAX_FRAME_LEN });
        }
        let total = 4 + len as usize;
        if avail.len() < total {
            return Ok(None);
        }
        let frame = Frame::decode(&avail[4..total])?;
        self.start += total;
        if self.start >= COMPACT_AT {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        Ok(Some(frame))
    }
}

/// Bounded outbound byte queue: encoded frames go in whole, bytes
/// drain out in whatever increments the kernel accepts. Segments are
/// kept frame-per-segment with a head offset rather than one flat
/// buffer, so a partially-written large frame never forces a memmove.
pub struct OutQueue {
    segs: VecDeque<Vec<u8>>,
    /// Bytes of `segs[0]` already written.
    head: usize,
    /// Total unwritten bytes across all segments.
    queued: usize,
    cap: usize,
}

impl Default for OutQueue {
    fn default() -> Self {
        OutQueue::with_cap(DEFAULT_OUTBOUND_CAP_BYTES)
    }
}

impl OutQueue {
    pub fn with_cap(cap: usize) -> OutQueue {
        OutQueue { segs: VecDeque::new(), head: 0, queued: 0, cap }
    }

    /// Unwritten bytes queued (the write-side component of the
    /// per-connection memory meter).
    pub fn queued_bytes(&self) -> usize {
        self.queued
    }

    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// Encode and enqueue one frame. Past the byte cap this is a typed
    /// [`QueueOverflow`] (tagged with `token` so the caller knows which
    /// connection to fail) and the frame is *not* queued.
    pub fn enqueue(&mut self, frame: &Frame, token: usize) -> Result<()> {
        let mut bytes = Vec::new();
        frame.write_to(&mut bytes)?; // length-prefixed, cap-checked
        if self.queued + bytes.len() > self.cap {
            bail!(QueueOverflow { token, queued: self.queued + bytes.len(), cap: self.cap });
        }
        self.queued += bytes.len();
        self.segs.push_back(bytes);
        Ok(())
    }

    /// Enqueue one pre-encoded protocol message as a `Msg` frame
    /// without re-copying the body: the 9-byte frame header and the
    /// message bytes go in as two segments (the drain loop already
    /// handles arbitrary segment boundaries, so a segment split inside
    /// a frame is invisible on the wire). Byte-identical to
    /// `enqueue(&Frame::Msg { bytes }, ..)` — the frame-encode rule of
    /// the zero-copy path — including the oversize and cap checks,
    /// which run against the header+body total before anything queues.
    pub fn enqueue_msg(&mut self, msg_bytes: Vec<u8>, token: usize) -> Result<()> {
        let header = msg_frame_header(msg_bytes.len())?; // cap-checked
        let total = header.len() + msg_bytes.len();
        if self.queued + total > self.cap {
            bail!(QueueOverflow { token, queued: self.queued + total, cap: self.cap });
        }
        self.queued += total;
        self.segs.push_back(header.to_vec());
        self.segs.push_back(msg_bytes);
        Ok(())
    }

    /// Drain as much as the writer accepts without blocking. Returns
    /// `Ok(true)` if the queue is now empty. `WouldBlock` stops the
    /// drain (leaving the rest for the next writable event),
    /// `Interrupted` retries, `Ok(0)` is a broken pipe.
    pub fn write_some(&mut self, w: &mut impl Write) -> io::Result<bool> {
        while let Some(seg) = self.segs.front() {
            match w.write(&seg[self.head..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "connection write returned zero",
                    ))
                }
                Ok(n) => {
                    self.head += n;
                    self.queued -= n;
                    if self.head == seg.len() {
                        self.segs.pop_front();
                        self.head = 0;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(true)
    }
}

/// One multiplexed connection: the nonblocking socket plus both
/// buffers and the interest currently registered with the poller.
pub struct Conn {
    pub stream: TcpStream,
    pub fd: RawFd,
    pub inbuf: FrameBuf,
    pub out: OutQueue,
    /// Interest currently registered (writable only while `out` is
    /// non-empty — the level-triggered no-spin rule).
    pub interest: Interest,
    /// Which client this connection identified as via `Hello`; None
    /// until the handshake frame arrives.
    pub client: Option<usize>,
}

/// What one readiness-driven read pass produced.
pub enum ReadOutcome {
    /// Socket drained to `WouldBlock`; connection still live.
    Open,
    /// Peer closed (EOF) or the read errored; the connection is gone.
    /// Frames already buffered were still returned.
    Closed(String),
}

impl Conn {
    pub fn new(stream: TcpStream, fd: RawFd) -> Conn {
        Conn {
            stream,
            fd,
            inbuf: FrameBuf::default(),
            out: OutQueue::default(),
            interest: Interest::READ,
            client: None,
        }
    }

    /// Buffered bytes held for this connection (read + write side) —
    /// what the `peak_conn_buffered_bytes` metric meters.
    pub fn buffered_bytes(&self) -> usize {
        self.inbuf.len() + self.out.queued_bytes()
    }

    /// Drain the readable socket into `inbuf`, then pop every complete
    /// frame into `frames`. Frame-level decode errors (garbage length,
    /// undecodable body) are reported as `Closed` — a peer speaking
    /// garbage is treated exactly like a vanished one, matching the
    /// reader-thread behavior in `net/tcp.rs`.
    pub fn read_ready(&mut self, frames: &mut Vec<Frame>) -> ReadOutcome {
        let mut chunk = [0u8; 64 << 10];
        let outcome = loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => break Some("peer closed".to_string()),
                Ok(n) => self.inbuf.extend(&chunk[..n]),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break None,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => break Some(format!("read failed: {e}")),
            }
        };
        loop {
            match self.inbuf.next_frame() {
                Ok(Some(f)) => frames.push(f),
                Ok(None) => break,
                Err(e) => return ReadOutcome::Closed(format!("bad frame: {e:#}")),
            }
        }
        match outcome {
            None => ReadOutcome::Open,
            Some(why) => ReadOutcome::Closed(why),
        }
    }

    /// Drain the outbound queue as far as the socket accepts.
    /// `Ok(true)` = queue empty (writable interest can drop).
    pub fn write_ready(&mut self) -> io::Result<bool> {
        self.out.write_some(&mut self.stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::party::Note;

    fn encoded(frames: &[Frame]) -> Vec<u8> {
        let mut buf = Vec::new();
        for f in frames {
            f.write_to(&mut buf).unwrap();
        }
        buf
    }

    #[test]
    fn framebuf_reassembles_byte_by_byte() {
        let frames = [
            Frame::Hello { client: 9 },
            Frame::Msg { bytes: vec![7; 300] },
            Frame::Note(Note::Loss { round: 1, loss: 0.5 }),
            Frame::Stop,
        ];
        let wire = encoded(&frames);
        let mut fb = FrameBuf::default();
        let mut got = Vec::new();
        // worst-case fragmentation: one byte per "read"
        for b in &wire {
            fb.extend(std::slice::from_ref(b));
            while let Some(f) = fb.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, frames);
        assert!(fb.is_empty(), "no residue after the last frame");
    }

    #[test]
    fn framebuf_handles_frames_split_across_chunks() {
        let frames = [Frame::Msg { bytes: vec![1; 100] }, Frame::Msg { bytes: vec![2; 100] }];
        let wire = encoded(&frames);
        let mut fb = FrameBuf::default();
        // a chunk boundary straddling the second frame's length prefix
        let cut = wire.len() / 2 + 3;
        fb.extend(&wire[..cut]);
        let first = fb.next_frame().unwrap();
        assert_eq!(first, Some(Frame::Msg { bytes: vec![1; 100] }));
        assert_eq!(fb.next_frame().unwrap(), None, "second frame incomplete");
        fb.extend(&wire[cut..]);
        assert_eq!(fb.next_frame().unwrap(), Some(Frame::Msg { bytes: vec![2; 100] }));
    }

    #[test]
    fn framebuf_rejects_oversize_length_before_allocating() {
        let mut fb = FrameBuf::default();
        fb.extend(&u32::MAX.to_le_bytes());
        let err = fb.next_frame().unwrap_err();
        let too_long = err.downcast_ref::<FrameTooLong>().expect("typed error");
        assert_eq!(too_long.len, u32::MAX as u64);
    }

    #[test]
    fn framebuf_compacts_consumed_prefix() {
        let frame = Frame::Msg { bytes: vec![3; 40 << 10] };
        let wire = encoded(&[frame]);
        let mut fb = FrameBuf::default();
        for _ in 0..4 {
            fb.extend(&wire);
            assert!(fb.next_frame().unwrap().is_some());
        }
        // after > 64 KiB of consumed frames the dead prefix was dropped
        assert!(fb.buf.len() < 2 * wire.len(), "compaction bounds the backing buffer");
        assert!(fb.is_empty());
    }

    #[test]
    fn outqueue_overflow_is_typed_and_rejects_the_frame() {
        let mut q = OutQueue::with_cap(64);
        q.enqueue(&Frame::Msg { bytes: vec![0; 16] }, 5).unwrap();
        let before = q.queued_bytes();
        let err = q.enqueue(&Frame::Msg { bytes: vec![0; 64] }, 5).unwrap_err();
        let of = err.downcast_ref::<QueueOverflow>().expect("typed overflow");
        assert_eq!(of.token, 5);
        assert_eq!(of.cap, 64);
        assert!(of.queued > of.cap);
        assert_eq!(q.queued_bytes(), before, "rejected frame was not queued");
    }

    #[test]
    fn enqueue_msg_drains_bit_identical_to_frame_enqueue() {
        // the zero-copy two-segment path must put the same bytes on
        // the wire as encoding a Frame::Msg — including across partial
        // writes that straddle the header/body segment boundary
        for len in [0usize, 1, 5, 300] {
            let bytes: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
            let mut via_frame = OutQueue::default();
            via_frame.enqueue(&Frame::Msg { bytes: bytes.clone() }, 0).unwrap();
            let mut via_msg = OutQueue::default();
            via_msg.enqueue_msg(bytes, 0).unwrap();
            assert_eq!(via_msg.queued_bytes(), via_frame.queued_bytes(), "len={len}");
            let mut a = Vec::new();
            let mut b = Vec::new();
            assert!(via_frame.write_some(&mut a).unwrap());
            let mut w = Throttle { sink: Vec::new(), budget: 0 };
            while !via_msg.is_empty() {
                w.budget = 4; // forces splits inside both segments
                via_msg.write_some(&mut w).unwrap();
            }
            b.extend_from_slice(&w.sink);
            assert_eq!(b, a, "len={len}");
        }
    }

    #[test]
    fn enqueue_msg_overflow_counts_header_plus_body() {
        let mut q = OutQueue::with_cap(32);
        // 9-byte header + 30-byte body = 39 > 32: rejected whole
        let err = q.enqueue_msg(vec![0; 30], 7).unwrap_err();
        let of = err.downcast_ref::<QueueOverflow>().expect("typed overflow");
        assert_eq!((of.token, of.cap, of.queued), (7, 32, 39));
        assert_eq!(q.queued_bytes(), 0, "rejected message was not queued");
        // 9 + 23 = 32 fits exactly
        q.enqueue_msg(vec![0; 23], 7).unwrap();
        assert_eq!(q.queued_bytes(), 32);
    }

    /// A writer that accepts a few bytes then reports `WouldBlock`,
    /// like a nonblocking socket with a tiny send buffer.
    struct Throttle {
        sink: Vec<u8>,
        budget: usize,
    }

    impl Write for Throttle {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.budget == 0 {
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "full"));
            }
            let n = buf.len().min(self.budget).min(7);
            self.sink.extend_from_slice(&buf[..n]);
            self.budget -= n;
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn outqueue_drains_across_partial_writes() {
        let frames =
            [Frame::Msg { bytes: vec![9; 50] }, Frame::Note(Note::RoundDone { round: 4 })];
        let mut q = OutQueue::default();
        for f in &frames {
            q.enqueue(f, 0).unwrap();
        }
        let total = q.queued_bytes();
        let mut w = Throttle { sink: Vec::new(), budget: 0 };
        // repeated writable events with a trickle of budget each time
        let mut rounds = 0;
        while !q.is_empty() {
            w.budget = 11;
            q.write_some(&mut w).unwrap();
            rounds += 1;
            assert!(rounds < 100, "drain must terminate");
        }
        assert!(rounds > 1, "the partial-write path was actually exercised");
        assert_eq!(w.sink.len(), total);
        assert_eq!(w.sink, encoded(&frames), "bytes drain in order, uncorrupted");
    }

    #[test]
    fn outqueue_write_zero_is_an_error() {
        struct Zero;
        impl Write for Zero {
            fn write(&mut self, _: &[u8]) -> io::Result<usize> {
                Ok(0)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let mut q = OutQueue::default();
        q.enqueue(&Frame::Stop, 0).unwrap();
        let e = q.write_some(&mut Zero).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::WriteZero);
    }
}
