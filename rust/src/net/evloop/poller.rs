//! Readiness multiplexing without dependencies: `epoll(7)` on Linux
//! via `extern "C"` declarations of the libc symbols std already
//! links, and a portable `poll(2)` fallback everywhere else (and on
//! Linux when forced, so the fallback path stays tested on the
//! platform CI actually runs).
//!
//! The abstraction is deliberately tiny — register/reregister/
//! deregister a raw fd under a caller-chosen `usize` token with a
//! read/write [`Interest`], then [`Poller::wait`] for a batch of
//! [`PollEvent`]s or a timeout. Level-triggered semantics on both
//! backends: an event repeats every wait until the caller drains the
//! socket (reads until `WouldBlock`) or drops the interest (writable
//! interest is only held while a connection's outbound queue is
//! non-empty, so there is no busy-spin on permanently-writable
//! sockets).

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// Which readiness a registration cares about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const WRITE: Interest = Interest { readable: false, writable: true };
    pub const BOTH: Interest = Interest { readable: true, writable: true };
}

/// One readiness report from [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    /// The token the fd was registered under.
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
    /// Error or hangup on the fd (`EPOLLERR`/`EPOLLHUP`/`POLLERR`/
    /// `POLLHUP`/`POLLNVAL`). The connection should be read to EOF and
    /// treated as gone.
    pub hangup: bool,
}

/// Which backend to build. `Auto` picks epoll on Linux (unless the
/// `VFL_EVLOOP_POLLER=poll` escape hatch is set) and `poll(2)`
/// elsewhere; `PollFallback` forces `poll(2)` so tests can exercise
/// the fallback deterministically without env-var races.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PollerKind {
    #[default]
    Auto,
    PollFallback,
}

impl PollerKind {
    pub fn build(self) -> io::Result<Poller> {
        match self {
            PollerKind::PollFallback => Ok(Poller::poll_fallback()),
            PollerKind::Auto => {
                if std::env::var("VFL_EVLOOP_POLLER").as_deref() == Ok("poll") {
                    return Ok(Poller::poll_fallback());
                }
                #[cfg(target_os = "linux")]
                {
                    epoll::Epoll::new().map(Poller::Epoll)
                }
                #[cfg(not(target_os = "linux"))]
                {
                    Ok(Poller::poll_fallback())
                }
            }
        }
    }
}

/// The readiness multiplexer: epoll-backed on Linux, `poll(2)`-backed
/// otherwise (or when forced).
pub enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(epoll::Epoll),
    Poll(PollVec),
}

impl Poller {
    fn poll_fallback() -> Poller {
        Poller::Poll(PollVec::default())
    }

    /// Human-readable backend name (for swarm reports / logs).
    pub fn name(&self) -> &'static str {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(_) => "epoll",
            Poller::Poll(_) => "poll",
        }
    }

    pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(e) => e.ctl(epoll::EPOLL_CTL_ADD, fd, token, interest),
            Poller::Poll(p) => p.register(fd, token, interest),
        }
    }

    pub fn reregister(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(e) => e.ctl(epoll::EPOLL_CTL_MOD, fd, token, interest),
            Poller::Poll(p) => p.register(fd, token, interest),
        }
    }

    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(e) => e.ctl(epoll::EPOLL_CTL_DEL, fd, 0, Interest::READ),
            Poller::Poll(p) => {
                p.deregister(fd);
                Ok(())
            }
        }
    }

    /// Block until readiness or `timeout` (None = forever). Clears and
    /// refills `events`; an empty result means the timeout elapsed.
    /// `EINTR` retries internally.
    pub fn wait(&mut self, events: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(e) => e.wait(events, timeout),
            Poller::Poll(p) => p.wait(events, timeout),
        }
    }
}

/// Saturate a `Duration` into the `c_int` milliseconds both syscalls
/// take (-1 = infinite). Sub-millisecond timeouts round *up* so a
/// 100µs stall floor never degenerates into a busy loop.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis();
            let ms = if ms == 0 && d.as_nanos() > 0 { 1 } else { ms };
            ms.min(i32::MAX as u128) as i32
        }
    }
}

#[cfg(target_os = "linux")]
pub mod epoll {
    //! The thin epoll shim: no libc crate, just the four symbols
    //! declared `extern "C"` — std links libc, so they resolve.
    use super::{timeout_ms, Interest, PollEvent};
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    // x86_64 Linux packs epoll_event to match the 32-bit layout; other
    // Linux targets use natural alignment. Matching the kernel ABI here
    // is the whole job of this struct.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Epoll {
        epfd: RawFd,
        buf: Vec<EpollEvent>,
    }

    impl Epoll {
        pub fn new() -> io::Result<Epoll> {
            // SAFETY: plain FFI call with no pointer arguments; the
            // returned fd is validated (< 0 => errno) before use.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Epoll { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] })
        }

        fn mask(interest: Interest) -> u32 {
            let mut m = EPOLLRDHUP;
            if interest.readable {
                m |= EPOLLIN;
            }
            if interest.writable {
                m |= EPOLLOUT;
            }
            m
        }

        pub fn ctl(&mut self, op: i32, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            let mut ev = EpollEvent { events: Self::mask(interest), data: token as u64 };
            let ep = if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
            // SAFETY: `ep` is either null (DEL, where the kernel ignores
            // it) or points at `ev`, which outlives the call.
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, ep) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn wait(&mut self, events: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
            let ms = timeout_ms(timeout);
            loop {
                // SAFETY: the kernel writes at most `buf.len()` events
                // into the live, exclusively-borrowed `self.buf`.
                let n = unsafe {
                    epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, ms)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(e);
                }
                for i in 0..n as usize {
                    // copy the (possibly packed) fields out before use
                    let ev = self.buf[i];
                    let bits = ev.events;
                    let data = ev.data;
                    events.push(PollEvent {
                        token: data as usize,
                        readable: bits & (EPOLLIN | EPOLLRDHUP) != 0,
                        writable: bits & EPOLLOUT != 0,
                        hangup: bits & (EPOLLERR | EPOLLHUP) != 0,
                    });
                }
                return Ok(());
            }
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            // SAFETY: `epfd` was returned by `epoll_create1`, is owned
            // exclusively by this struct, and is closed exactly once.
            unsafe { close(self.epfd) };
        }
    }
}

mod sys_poll {
    //! `poll(2)` via the same extern-declaration trick. The `nfds_t`
    //! type differs per platform (`c_ulong` on Linux, `c_uint` on the
    //! BSDs/macOS), so it is cfg'd here.
    use std::os::unix::io::RawFd;

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    #[cfg(target_os = "linux")]
    pub type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    pub type NfdsT = std::os::raw::c_uint;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
    }
}

/// The portable fallback: a flat registration table rebuilt into a
/// `pollfd` array per wait. O(n) per call where epoll is O(ready) —
/// fine for correctness testing and modest fan-ins, which is exactly
/// what the fallback is for.
#[derive(Default)]
pub struct PollVec {
    regs: Vec<(RawFd, usize, Interest)>,
}

impl PollVec {
    fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        self.deregister(fd);
        self.regs.push((fd, token, interest));
        Ok(())
    }

    fn deregister(&mut self, fd: RawFd) {
        self.regs.retain(|&(f, _, _)| f != fd);
    }

    fn wait(&mut self, events: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        let mut fds: Vec<sys_poll::PollFd> = self
            .regs
            .iter()
            .map(|&(fd, _, interest)| {
                let mut ev = 0i16;
                if interest.readable {
                    ev |= sys_poll::POLLIN;
                }
                if interest.writable {
                    ev |= sys_poll::POLLOUT;
                }
                sys_poll::PollFd { fd, events: ev, revents: 0 }
            })
            .collect();
        let ms = timeout_ms(timeout);
        loop {
            // SAFETY: `fds` is a live Vec of `#[repr(C)]` PollFd; the
            // kernel reads/writes exactly `fds.len()` entries.
            let n = unsafe {
                sys_poll::poll(fds.as_mut_ptr(), fds.len() as sys_poll::NfdsT, ms)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(e);
            }
            break;
        }
        for (pfd, &(_, token, _)) in fds.iter().zip(&self.regs) {
            let r = pfd.revents;
            if r == 0 {
                continue;
            }
            events.push(PollEvent {
                token,
                readable: r & sys_poll::POLLIN != 0,
                writable: r & sys_poll::POLLOUT != 0,
                hangup: r & (sys_poll::POLLERR | sys_poll::POLLHUP | sys_poll::POLLNVAL) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    fn backends() -> Vec<Poller> {
        let mut v = vec![PollerKind::PollFallback.build().unwrap()];
        if let Ok(p) = PollerKind::Auto.build() {
            v.push(p);
        }
        v
    }

    #[test]
    fn timeout_rounds_up_not_to_zero() {
        assert_eq!(timeout_ms(None), -1);
        assert_eq!(timeout_ms(Some(Duration::from_micros(100))), 1);
        assert_eq!(timeout_ms(Some(Duration::ZERO)), 0);
        assert_eq!(timeout_ms(Some(Duration::from_secs(u64::MAX))), i32::MAX);
    }

    #[test]
    fn readable_after_peer_write_on_every_backend() {
        for mut p in backends() {
            let (mut a, b) = pair();
            b.set_nonblocking(true).unwrap();
            p.register(b.as_raw_fd(), 7, Interest::READ).unwrap();
            let mut evs = Vec::new();
            // nothing yet: a short wait times out with no events
            p.wait(&mut evs, Some(Duration::from_millis(10))).unwrap();
            assert!(evs.is_empty(), "{}: spurious readiness", p.name());
            a.write_all(b"hi").unwrap();
            p.wait(&mut evs, Some(Duration::from_secs(5))).unwrap();
            assert_eq!(evs.len(), 1, "{}", p.name());
            assert_eq!(evs[0].token, 7);
            assert!(evs[0].readable);
            let mut buf = [0u8; 8];
            let n = (&b).read(&mut buf).unwrap();
            assert_eq!(&buf[..n], b"hi");
        }
    }

    #[test]
    fn writable_interest_and_deregister() {
        for mut p in backends() {
            let (a, _b) = pair();
            a.set_nonblocking(true).unwrap();
            p.register(a.as_raw_fd(), 3, Interest::BOTH).unwrap();
            let mut evs = Vec::new();
            p.wait(&mut evs, Some(Duration::from_secs(5))).unwrap();
            assert!(
                evs.iter().any(|e| e.token == 3 && e.writable),
                "{}: fresh socket is writable",
                p.name()
            );
            // drop writable interest: no more events, wait times out
            p.reregister(a.as_raw_fd(), 3, Interest::READ).unwrap();
            p.wait(&mut evs, Some(Duration::from_millis(10))).unwrap();
            assert!(evs.is_empty(), "{}: read-only interest is quiet", p.name());
            p.deregister(a.as_raw_fd()).unwrap();
            p.wait(&mut evs, Some(Duration::from_millis(10))).unwrap();
            assert!(evs.is_empty(), "{}: deregistered fd is silent", p.name());
        }
    }

    #[test]
    fn hangup_reported_when_peer_drops() {
        for mut p in backends() {
            let (a, b) = pair();
            b.set_nonblocking(true).unwrap();
            p.register(b.as_raw_fd(), 1, Interest::READ).unwrap();
            drop(a);
            let mut evs = Vec::new();
            p.wait(&mut evs, Some(Duration::from_secs(5))).unwrap();
            // a dropped peer shows up as readable-to-EOF and/or hangup;
            // either way the event fires and a read returns Ok(0)
            assert_eq!(evs.len(), 1, "{}", p.name());
            assert!(evs[0].readable || evs[0].hangup, "{}", p.name());
        }
    }
}
