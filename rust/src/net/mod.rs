//! Network substrate: wire format, byte metering, and the pluggable
//! transports that carry the §4 protocol.
//!
//! * [`wire`] — the little-endian length-prefixed encoding primitives.
//! * [`transport`] — [`Network`] (the per-(phase, party, direction)
//!   byte counters behind Table 2), the [`Transport`] trait, and the
//!   deterministic single-threaded [`SimTransport`].
//! * [`threaded`] — [`ThreadedTransport`]: one OS thread per party,
//!   channels in between, bit-identical results to the simulator.
//! * [`frame`] / [`tcp`] — length-prefixed socket framing and the
//!   cross-process `serve`/`join` plumbing.
//! * [`faulty`] — deterministic fault injection ([`FaultPlan`],
//!   [`FaultyTransport`]): seeded crash/drop/delay schedules applied
//!   identically on every transport, the proof harness for the
//!   dropout-tolerant protocol.

pub mod faulty;
pub mod frame;
pub mod tcp;
pub mod threaded;
pub mod transport;
pub mod wire;

pub use faulty::{Fault, FaultPlan, FaultyParty, FaultyTransport};
pub use threaded::ThreadedTransport;
pub use transport::{Addr, Network, Phase, SimTransport, Transport, TransportOutcome};
pub use wire::{Reader, Writer};
