//! Network substrate: wire format, byte metering, and the pluggable
//! transports that carry the §4 protocol.
//!
//! * [`wire`] — the little-endian length-prefixed encoding primitives.
//! * [`transport`] — [`Network`] (the per-(phase, party, direction)
//!   byte counters behind Table 2), the [`Transport`] trait, the
//!   deterministic single-threaded [`SimTransport`], and the adaptive
//!   [`StallClock`] quiescence policy shared by the timeout-based
//!   transports.
//! * [`threaded`] — [`ThreadedTransport`]: one OS thread per party,
//!   channels in between, bit-identical results to the simulator.
//! * [`frame`] / [`tcp`] — length-prefixed socket framing (bodies are
//!   capped at [`frame::MAX_FRAME_LEN`] on both the write and the read
//!   side, with the typed [`frame::FrameTooLong`] error) and the
//!   cross-process `serve`/`join` plumbing.
//! * [`faulty`] — deterministic fault injection ([`FaultPlan`],
//!   [`FaultyTransport`]): seeded crash/drop/delay/corrupt schedules
//!   applied identically on every transport, the proof harness for the
//!   dropout-tolerant protocol. Faults count messages, so under the
//!   chunked streaming pipeline they land on individual chunks.
//!
//! Every transport carries chunked masked tensors (`Msg::MaskedChunk`
//! uplink, `Msg::GradientChunk` downlink) exactly like any other
//! protocol message: the simulator pumps them through its global FIFO,
//! the threaded transport through per-party channels, TCP inside
//! [`frame`]s — the per-sender FIFO guarantee each transport already
//! provides is the only ordering the chunk assembler needs. Whether
//! the aggregator folds those chunks inline or across `--agg-workers`
//! shard workers is invisible to the transport (and to every output
//! bit).

pub mod faulty;
pub mod frame;
pub mod tcp;
pub mod threaded;
pub mod transport;
pub mod wire;

pub use faulty::{Fault, FaultPlan, FaultyParty, FaultyTransport};
pub use frame::{FrameTooLong, MAX_FRAME_LEN};
pub use threaded::ThreadedTransport;
pub use transport::{Addr, Network, Phase, SimTransport, StallClock, Transport, TransportOutcome};
pub use wire::{Reader, Writer};
