//! Simulated network substrate: wire format + byte-metered transport.

pub mod transport;
pub mod wire;

pub use transport::{Addr, Network, Phase};
pub use wire::{Reader, Writer};
