//! Network substrate: wire format, byte metering, and the pluggable
//! transports that carry the §4 protocol.
//!
//! * [`wire`] — the little-endian length-prefixed encoding primitives.
//! * [`transport`] — [`Network`] (the per-(phase, party, direction)
//!   byte counters behind Table 2), the [`Transport`] trait, the
//!   deterministic single-threaded [`SimTransport`], and the adaptive
//!   [`StallClock`] quiescence policy shared by the timeout-based
//!   transports.
//! * [`threaded`] — [`ThreadedTransport`]: one OS thread per party,
//!   channels in between, bit-identical results to the simulator.
//! * [`frame`] / [`tcp`] — length-prefixed socket framing (bodies are
//!   capped at [`frame::MAX_FRAME_LEN`] on both the write and the read
//!   side, with the typed [`frame::FrameTooLong`] error) and the
//!   cross-process `serve`/`join` plumbing, one thread per connection
//!   with blocking I/O (writes are bounded by
//!   [`tcp::DEFAULT_WRITE_TIMEOUT`] and surface the typed
//!   [`tcp::WriteStalled`] error instead of deadlocking). [`tcp::leaf`]
//!   is the distributed half of the `--leaves` fan-in tree
//!   ([`crate::coordinator::topology`]): a relay process that owns one
//!   client shard's sockets, folds its masked fan-in into
//!   `Msg::PartialSum` partials upstream, relays everything else
//!   verbatim on the sender's own uplink (per-sender FIFO preserved),
//!   and sniffs downstream `DropoutNotice`s to purge and re-emit
//!   corrected partials.
//! * [`evloop`] (unix) — [`EvloopTransport`]: the same sockets and
//!   frames, multiplexed on a *single* readiness-driven event-loop
//!   thread (epoll on Linux, portable `poll(2)` fallback). No thread
//!   per client and no blocking writes anywhere, which is what scales
//!   the aggregator to 10k+ concurrent clients — `vfl-sa swarm`
//!   demonstrates it live.
//! * [`faulty`] — deterministic fault injection ([`FaultPlan`],
//!   [`FaultyTransport`]): seeded crash/drop/delay/corrupt schedules
//!   applied identically on every transport, the proof harness for the
//!   dropout-tolerant protocol. Faults count messages, so under the
//!   chunked streaming pipeline they land on individual chunks.
//!
//! # The four-transport story
//!
//! All four transports run the *same* party state machines over the
//! *same* message codec and produce bit-identical reports; they differ
//! only in who moves the bytes:
//!
//! | transport | concurrency | bytes move via | scales to |
//! |---|---|---|---|
//! | `SimTransport` | none (deterministic loop) | global FIFO | debugging |
//! | `ThreadedTransport` | thread per party | channels | tens |
//! | `tcp` | thread per connection | blocking sockets | hundreds |
//! | `evloop` | one event-loop thread | nonblocking sockets | 10k+ |
//!
//! Every transport carries chunked masked tensors (`Msg::MaskedChunk`
//! uplink, `Msg::GradientChunk` downlink) exactly like any other
//! protocol message: the simulator pumps them through its global FIFO,
//! the threaded transport through per-party channels, the socket
//! transports inside [`frame`]s — the per-sender FIFO guarantee each
//! transport already provides is the only ordering the chunk assembler
//! needs. Whether the aggregator folds those chunks inline or across
//! `--agg-workers` shard workers is invisible to the transport (and to
//! every output bit). The same holds for the `--leaves` fan-in tree:
//! on every in-process transport the `TreeAggregator` wrapper sits
//! behind the ordinary [`Party`](crate::coordinator::Party) seam, so
//! the bytes on the wire are identical to a flat run; only the
//! distributed `vfl-sa leaf` deployment moves the leaf fold into
//! separate processes (and there the root's Table-2 receive counters
//! drop to the L·d partial-sum volume — the point of the tree).

#[cfg(unix)]
pub mod evloop;
pub mod faulty;
pub mod frame;
pub mod tcp;
pub mod threaded;
pub mod transport;
pub mod wire;

#[cfg(unix)]
pub use evloop::EvloopTransport;
pub use faulty::{Fault, FaultPlan, FaultyParty, FaultyTransport};
pub use frame::{FrameTooLong, MAX_FRAME_LEN};
pub use tcp::{WriteStalled, DEFAULT_WRITE_TIMEOUT};
pub use threaded::ThreadedTransport;
pub use transport::{Addr, Network, Phase, SimTransport, StallClock, Transport, TransportOutcome};
pub use wire::{Reader, Writer};
