//! Minimal binary wire format (no external serde in this sandbox).
//!
//! Little-endian, length-prefixed. Every protocol message in
//! [`crate::coordinator::messages`] encodes through these primitives,
//! and the transport's byte counters (Table 2) meter exactly these
//! bytes.

use anyhow::{bail, Result};

/// Append-only encoder.
#[derive(Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    /// Encoder with `n` bytes pre-reserved. The zero-copy message path
    /// sizes the buffer exactly (`Msg::encoded_len`) so one allocation
    /// carries header + payload all the way to the socket.
    pub fn with_capacity(n: usize) -> Self {
        Writer { buf: Vec::with_capacity(n) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    pub fn fixed<const N: usize>(&mut self, v: &[u8; N]) {
        self.buf.extend_from_slice(v);
    }

    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn u64s(&mut self, v: &[u64]) {
        self.u32(v.len() as u32);
        self.u64s_raw(v);
    }

    /// Append `v` as little-endian u64 words with NO count prefix —
    /// the chunk builders write their own headers. On little-endian
    /// targets this is a single `memcpy`; the per-word fallback keeps
    /// big-endian targets bit-identical on the wire.
    pub fn u64s_raw(&mut self, v: &[u64]) {
        #[cfg(target_endian = "little")]
        self.buf.extend_from_slice(u64s_as_le_bytes(v));
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// View a u64 slice as its little-endian wire bytes.
#[cfg(target_endian = "little")]
#[inline]
fn u64s_as_le_bytes(v: &[u64]) -> &[u8] {
    // SAFETY: u64 has no padding or invalid bit patterns, u8's
    // alignment of 1 divides u64's, and the returned borrow is tied to
    // `v`'s lifetime. On a little-endian target the in-memory byte
    // order IS the wire order (pinned bit-identical to the per-word
    // `to_le_bytes` path in the tests below).
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 8) }
}

/// Cursor-based decoder.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("wire: truncated message (want {n} at {}, len {})", self.pos, self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn fixed<const N: usize>(&mut self) -> Result<[u8; N]> {
        Ok(self.take(N)?.try_into().unwrap())
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 8)?;
        let mut out = vec![0u64; n];
        #[cfg(target_endian = "little")]
        // SAFETY: `out` owns n*8 writable bytes, `raw` holds exactly
        // n*8 bytes (take() checked), and a fresh allocation cannot
        // overlap the input buffer.
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr().cast::<u8>(), n * 8);
        }
        #[cfg(not(target_endian = "little"))]
        for (o, c) in out.iter_mut().zip(raw.chunks_exact(8)) {
            *o = u64::from_le_bytes(c.try_into().unwrap());
        }
        Ok(out)
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u16(300);
        w.u32(70_000);
        w.u64(1 << 40);
        w.f32(-1.5);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert!(r.done());
    }

    #[test]
    fn vectors_roundtrip() {
        let mut w = Writer::new();
        w.bytes(b"hello");
        w.f32s(&[1.0, -2.0, 3.5]);
        w.u64s(&[u64::MAX, 0, 42]);
        w.fixed(&[9u8; 32]);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.bytes().unwrap(), b"hello");
        assert_eq!(r.f32s().unwrap(), vec![1.0, -2.0, 3.5]);
        assert_eq!(r.u64s().unwrap(), vec![u64::MAX, 0, 42]);
        assert_eq!(r.fixed::<32>().unwrap(), [9u8; 32]);
        assert!(r.done());
    }

    #[test]
    fn truncation_detected() {
        let mut w = Writer::new();
        w.u64s(&[1, 2, 3]);
        let buf = w.finish();
        let mut r = Reader::new(&buf[..buf.len() - 1]);
        assert!(r.u64s().is_err());
        let mut r2 = Reader::new(&[]);
        assert!(r2.u32().is_err());
    }

    #[test]
    fn u64s_raw_matches_per_word_encoding() {
        // the bulk byte-view path must emit exactly the bytes the
        // per-word to_le_bytes loop emits (the wire is LE by contract)
        let vals = [0u64, 1, u64::MAX, 0x0102030405060708, 0xdeadbeefcafebabe];
        let mut w = Writer::new();
        w.u64s_raw(&vals);
        let mut want = Vec::new();
        for v in vals {
            want.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(w.finish(), want);
        // and `u64s` == count prefix + raw body
        let mut a = Writer::new();
        a.u64s(&vals);
        let mut b = Writer::new();
        b.u32(vals.len() as u32);
        b.u64s_raw(&vals);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn with_capacity_changes_nothing_on_the_wire() {
        let mut a = Writer::new();
        let mut b = Writer::with_capacity(64);
        for w in [&mut a, &mut b] {
            w.u8(9);
            w.u64s(&[7, 8, 9]);
        }
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn sizes_are_tight() {
        let mut w = Writer::new();
        w.f32s(&[0.0; 100]);
        assert_eq!(w.finish().len(), 4 + 400);
        let mut w = Writer::new();
        w.u64s(&[0; 100]);
        assert_eq!(w.finish().len(), 4 + 800);
    }
}
