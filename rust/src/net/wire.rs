//! Minimal binary wire format (no external serde in this sandbox).
//!
//! Little-endian, length-prefixed. Every protocol message in
//! [`crate::coordinator::messages`] encodes through these primitives,
//! and the transport's byte counters (Table 2) meter exactly these
//! bytes.

use anyhow::{bail, Result};

/// Append-only encoder.
#[derive(Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    pub fn fixed<const N: usize>(&mut self, v: &[u8; N]) {
        self.buf.extend_from_slice(v);
    }

    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn u64s(&mut self, v: &[u64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-based decoder.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("wire: truncated message (want {n} at {}, len {})", self.pos, self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn fixed<const N: usize>(&mut self) -> Result<[u8; N]> {
        Ok(self.take(N)?.try_into().unwrap())
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u16(300);
        w.u32(70_000);
        w.u64(1 << 40);
        w.f32(-1.5);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert!(r.done());
    }

    #[test]
    fn vectors_roundtrip() {
        let mut w = Writer::new();
        w.bytes(b"hello");
        w.f32s(&[1.0, -2.0, 3.5]);
        w.u64s(&[u64::MAX, 0, 42]);
        w.fixed(&[9u8; 32]);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.bytes().unwrap(), b"hello");
        assert_eq!(r.f32s().unwrap(), vec![1.0, -2.0, 3.5]);
        assert_eq!(r.u64s().unwrap(), vec![u64::MAX, 0, 42]);
        assert_eq!(r.fixed::<32>().unwrap(), [9u8; 32]);
        assert!(r.done());
    }

    #[test]
    fn truncation_detected() {
        let mut w = Writer::new();
        w.u64s(&[1, 2, 3]);
        let buf = w.finish();
        let mut r = Reader::new(&buf[..buf.len() - 1]);
        assert!(r.u64s().is_err());
        let mut r2 = Reader::new(&[]);
        assert!(r2.u32().is_err());
    }

    #[test]
    fn sizes_are_tight() {
        let mut w = Writer::new();
        w.f32s(&[0.0; 100]);
        assert_eq!(w.finish().len(), 4 + 400);
        let mut w = Writer::new();
        w.u64s(&[0; 100]);
        assert_eq!(w.finish().len(), 4 + 800);
    }
}
