//! Cross-process transport: the aggregator (plus driver) serves TCP,
//! every client party joins over a socket — `vfl-sa serve` / `vfl-sa
//! join` in `main.rs`.
//!
//! The star topology maps one-to-one onto sockets: each client holds a
//! single connection to the server, which relays nothing client-to-
//! client (the §4 protocol never needs it). Round-boundary controls
//! and driver notes ride the same connection as [`Frame`]s. The server
//! meters the *inner* protocol-message encodings through a [`Network`],
//! so a socket run reports the same Table-2 byte counters as the
//! simulator; framing overhead is transport cost and deliberately
//! uncounted.
//!
//! Every process builds the same deterministic synthetic dataset from
//! the shared `RunConfig` seed, so no raw features ever cross a
//! socket that wouldn't in the simulated protocol.
//!
//! Blocking writes and the deadlock bound
//! --------------------------------------
//! This transport writes frames with blocking `write_all` on both
//! sides. When the server is mid-broadcast of a large frame while
//! clients are simultaneously mid-write of large chunked tensors,
//! both directions' socket buffers can fill and both ends block in
//! `write` forever — a classic distributed write-write deadlock. All
//! sockets therefore arm [`DEFAULT_WRITE_TIMEOUT`]: a write stalled
//! past it fails with the typed [`WriteStalled`] error (the server
//! marks that client dropped; a client surfaces it as its failure)
//! instead of hanging the run. The timeout is a bound, not a fix —
//! the real fix is the [`evloop`](super::evloop) transport, whose
//! event loop never issues a blocking write at all.

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::thread;

use anyhow::{bail, Context, Result};

use crate::coordinator::messages::Msg;
use crate::coordinator::parties::{TAG_ACTIVATION, TAG_GRADIENT};
use crate::coordinator::party::{Note, Outbox, Party, RoundSpec};
use crate::coordinator::topology::LeafAggregator;
use crate::coordinator::window::RoundWindow;
use crate::coordinator::{Metrics, StreamCfg};

use super::frame::Frame;
use super::transport::{StallClock, MAX_IDLE_PROBES};
use super::{Addr, Network};

/// What a completed `serve` run hands back.
pub struct ServeOutcome {
    /// Driver notes: losses, predictions, round completions.
    pub notes: Vec<Note>,
    /// Table-2 byte counters, metered server-side (every protocol
    /// message crosses the aggregator in a star topology).
    pub net: Network,
    /// The aggregator's CPU meters (clients report their own locally).
    pub metrics: Metrics,
}

enum Event {
    Frame(usize, Frame),
    Gone(usize, String),
}

/// How long a blocking frame write may stall before it fails with
/// [`WriteStalled`] instead of deadlocking (see the module docs).
pub const DEFAULT_WRITE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

/// Typed error for a blocking socket write that exhausted
/// [`DEFAULT_WRITE_TIMEOUT`]: the peer stopped draining its receive
/// buffer, the would-be-deadlock case. Callers can downcast an
/// `anyhow::Error` to this to tell a stalled peer from other
/// transport failures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WriteStalled {
    /// The exhausted timeout.
    pub timeout: std::time::Duration,
}

impl std::fmt::Display for WriteStalled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "socket write stalled past {:?} (peer not draining; the write-write deadlock \
             the evloop transport avoids by design)",
            self.timeout
        )
    }
}

impl std::error::Error for WriteStalled {}

/// Map a frame-write failure whose root cause is an expired write
/// timeout (`WouldBlock`/`TimedOut` — platforms differ) to the typed
/// [`WriteStalled`] error. The streams are always blocking, so those
/// kinds can only mean the timeout fired.
fn stall_context(e: anyhow::Error) -> anyhow::Error {
    let stalled = e.root_cause().downcast_ref::<std::io::Error>().is_some_and(|io| {
        matches!(
            io.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        )
    });
    if stalled {
        e.context(WriteStalled { timeout: DEFAULT_WRITE_TIMEOUT })
    } else {
        e
    }
}

/// Write one frame through a socket with a write timeout armed. Every
/// control-frame write in this module goes through here.
fn write_frame(w: &mut impl Write, f: &Frame) -> Result<()> {
    f.write_to(w).map_err(stall_context)
}

/// Write one `Msg` frame from pre-encoded message bytes — the
/// zero-copy twin of `write_frame(w, &Frame::Msg { bytes })`, same
/// byte stream ([`frame::write_msg_to`]) and the same [`WriteStalled`]
/// mapping, without re-wrapping the bytes in a frame-body `Vec`.
fn write_msg_frame(w: &mut impl Write, msg_bytes: &[u8]) -> Result<()> {
    super::frame::write_msg_to(w, msg_bytes).map_err(stall_context)
}

// The server's quiescence window before probing the aggregator for
// dropped parties ([`Party::on_stall`]) is the same adaptive
// [`StallClock`] the threaded transport uses (EWMA of inter-frame
// gaps between a configurable floor and cap), passed in by the caller
// so `--stall-cap-ms` and the test-shrunk floor apply to socket runs
// too.

/// Route an aggregator outbox to the client sockets, metering each
/// protocol message. Writes to clients whose sockets died are skipped
/// — a dead socket is a dropped party, which the aggregator's stall
/// probe handles; it is not the server's error. Scheduler-control
/// notes (`WindowDrain`, and `RoundDone` should the aggregator ever
/// emit one) feed the round window instead of the result notes.
fn route_server(
    net: &mut Network,
    writers: &mut [Option<TcpStream>],
    ob: Outbox,
    notes: &mut Vec<Note>,
    win: &mut RoundWindow,
) -> Result<()> {
    for (to, msg) in ob.msgs {
        let Addr::Client(ci) = to else { bail!("aggregator addressed itself") };
        let bytes = msg.into_bytes();
        net.meter(Addr::Aggregator, to, bytes.len());
        if let Some(w) = writers[ci].as_mut() {
            if let Err(e) = write_msg_frame(w, &bytes) {
                eprintln!("serve: client {ci} write failed ({e:#}), marking dropped");
                writers[ci] = None;
            }
        }
    }
    for n in ob.notes {
        if let Some(n) = win.observe(n) {
            notes.push(n);
        }
    }
    Ok(())
}

/// Host the aggregator: accept `n_clients` joins, run the schedule
/// with up to `window` rounds in flight (`--rounds-in-flight`; 1 =
/// strictly serial), return the run's notes and byte counters. `clock`
/// is the adaptive dropout-detection window (`StallClock::from_config`
/// wires the `--stall-cap-ms` / test-floor knobs through).
pub fn serve(
    listen: &str,
    aggregator: Box<dyn Party + '_>,
    schedule: &[RoundSpec],
    n_clients: usize,
    clock: StallClock,
    window: usize,
) -> Result<ServeOutcome> {
    let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
    serve_on(listener, aggregator, schedule, n_clients, clock, window)
}

/// [`serve`] on an already-bound listener (lets tests bind port 0 and
/// learn the real port before clients race to connect).
pub fn serve_on(
    listener: TcpListener,
    mut aggregator: Box<dyn Party + '_>,
    schedule: &[RoundSpec],
    n_clients: usize,
    mut clock: StallClock,
    window: usize,
) -> Result<ServeOutcome> {
    let listen = listener.local_addr().map(|a| a.to_string()).unwrap_or_default();
    eprintln!("serve: listening on {listen}, waiting for {n_clients} client(s)");

    let (tx, rx) = channel::<Event>();
    let mut writers: Vec<Option<TcpStream>> = (0..n_clients).map(|_| None).collect();
    let mut connected = 0usize;
    while connected < n_clients {
        let (stream, peer) = listener.accept().context("accept")?;
        stream.set_nodelay(true).ok();
        // bound the blocking-write deadlock (see the module docs)
        stream.set_write_timeout(Some(DEFAULT_WRITE_TIMEOUT)).ok();
        let mut reader = stream.try_clone().context("clone stream")?;
        let hello = Frame::read_from(&mut reader)?;
        let Frame::Hello { client } = hello else { bail!("expected Hello, got {hello:?}") };
        let ci = client as usize;
        if ci >= n_clients {
            bail!("client index {ci} out of range (need 0..{n_clients})");
        }
        if writers[ci].is_some() {
            bail!("client {ci} connected twice");
        }
        eprintln!("serve: client {ci} joined from {peer}");
        writers[ci] = Some(stream);
        let tx = tx.clone();
        thread::spawn(move || loop {
            match Frame::read_from(&mut reader) {
                Ok(f) => {
                    if tx.send(Event::Frame(ci, f)).is_err() {
                        break;
                    }
                }
                Err(e) => {
                    let _ = tx.send(Event::Gone(ci, format!("{e:#}")));
                    break;
                }
            }
        });
        connected += 1;
    }
    drop(tx);
    // The accept loop above only exits once every slot is filled; a
    // hole here is a bookkeeping bug, surfaced as a typed error rather
    // than a server panic mid-handshake.
    let mut writers: Vec<Option<TcpStream>> = writers
        .into_iter()
        .enumerate()
        .map(|(ci, w)| {
            w.map(Some).with_context(|| format!("client {ci} never completed its join"))
        })
        .collect::<Result<_>>()?;

    let mut net = Network::new(n_clients);
    let mut notes: Vec<Note> = Vec::new();
    let mut last_event = std::time::Instant::now();
    let mut win = RoundWindow::new(schedule, window);
    let mut idle_probes = 0u32;
    let mut processed_since_probe = 0u64;
    while !win.done() {
        // open every round the window allows, in schedule order:
        // boundary first, on every socket, so each client orders the
        // round ahead of its first protocol message. Only the active
        // party (client 0) receives the batch ids: shipping them to a
        // passive would leak exactly the batch membership the sealed-ID
        // broadcast (§4.0.2) exists to hide.
        while let Some(spec) = win.next_start() {
            net.phase = spec.phase;
            for (ci, w) in writers.iter_mut().enumerate() {
                let Some(sock) = w.as_mut() else { continue };
                let for_client = if ci == 0 {
                    spec.clone()
                } else {
                    RoundSpec { ids: Vec::new(), ..spec.clone() }
                };
                if let Err(e) = write_frame(sock, &Frame::Round(for_client)) {
                    eprintln!("serve: client {ci} write failed ({e:#}), marking dropped");
                    *w = None;
                }
            }
            let mut ob = Outbox::default();
            aggregator.on_round_start(spec, &mut ob)?;
            route_server(&mut net, &mut writers, ob, &mut notes, &mut win)?;
        }
        let event = match rx.recv_timeout(clock.timeout()) {
            Ok(ev) => {
                let now = std::time::Instant::now();
                clock.observe_gap(now - last_event);
                last_event = now;
                ev
            }
            Err(RecvTimeoutError::Timeout) => {
                // no frame for the stall window: ask the aggregator
                // whether recovery can declare the silent clients
                // dropped (timeout-based dropout detection). Only
                // probe when truly quiescent — a timeout right
                // after a burst of traffic is not a dropout. Reset
                // the gap anchor so stall windows never feed the
                // EWMA (the clock tracks frame cadence, not its
                // own timeouts).
                last_event = std::time::Instant::now();
                let mut ob = Outbox::default();
                if processed_since_probe == 0 {
                    aggregator.on_stall(&mut ob)?;
                }
                let acted = !ob.msgs.is_empty() || !ob.notes.is_empty();
                route_server(&mut net, &mut writers, ob, &mut notes, &mut win)?;
                if acted || processed_since_probe > 0 {
                    idle_probes = 0;
                } else {
                    idle_probes += 1;
                    if idle_probes >= MAX_IDLE_PROBES {
                        bail!(
                            "protocol stalled: round {} never completed",
                            win.oldest_in_flight().unwrap_or(0)
                        );
                    }
                }
                processed_since_probe = 0;
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => {
                bail!("all client connections lost")
            }
        };
        match event {
            Event::Gone(ci, e) => {
                // a vanished client is a dropped party, not a server
                // error: close its writer and let the stall probe
                // (or an already-complete fan-in) handle it
                eprintln!("serve: client {ci} disconnected ({e}), marking dropped");
                writers[ci] = None;
            }
            Event::Frame(ci, Frame::Msg { bytes }) => {
                idle_probes = 0;
                processed_since_probe += 1;
                net.meter(Addr::Client(ci), Addr::Aggregator, bytes.len());
                let msg = Msg::decode(&bytes)?;
                let mut ob = Outbox::default();
                aggregator.on_message(Addr::Client(ci), msg, &mut ob)?;
                route_server(&mut net, &mut writers, ob, &mut notes, &mut win)?;
            }
            Event::Frame(_, Frame::Note(n)) => {
                idle_probes = 0;
                processed_since_probe += 1;
                match n {
                    Note::Failed { who, error } => bail!("party {who} failed: {error}"),
                    n => {
                        if let Some(n) = win.observe(n) {
                            if let Note::RoundDone { round } = &n {
                                // scheduler bookkeeping for the
                                // server-side aggregator
                                aggregator.on_round_complete(*round);
                            }
                            notes.push(n);
                        }
                    }
                }
            }
            Event::Frame(ci, f) => bail!("unexpected frame from client {ci}: {f:?}"),
        }
    }
    for w in writers.iter_mut().flatten() {
        let _ = Frame::Stop.write_to(w);
    }
    let mut metrics = aggregator.take_metrics();
    metrics.record_pipeline(win.stats());
    Ok(ServeOutcome { notes, net, metrics })
}

/// Run one client party against a serving aggregator. Returns the
/// party's CPU meters once the server signals Stop.
pub fn join(connect: &str, client: usize, mut party: Box<dyn Party + '_>) -> Result<Metrics> {
    join_addr(connect, client, &mut *party)?;
    Ok(party.take_metrics())
}

/// [`join`] against a *borrowed* party: connect, handshake, run the
/// client loop. The in-process `EvloopTransport` (`super::evloop`)
/// reuses this and keeps the boxed party for harvesting its meters
/// and final parameters afterwards.
pub(crate) fn join_addr(connect: &str, client: usize, party: &mut dyn Party) -> Result<()> {
    let mut stream = TcpStream::connect(connect).with_context(|| format!("connect {connect}"))?;
    stream.set_nodelay(true).ok();
    // bound the blocking-write deadlock (see the module docs)
    stream.set_write_timeout(Some(DEFAULT_WRITE_TIMEOUT)).ok();
    write_frame(&mut stream, &Frame::Hello { client: client as u16 })?;
    eprintln!("join: client {client} connected to {connect}");

    let result = client_loop(party, &mut stream);
    if let Err(e) = &result {
        // best-effort: surface the failure to the server before dying
        let _ = Frame::Note(Note::Failed {
            who: (client + 1) as u16,
            error: format!("{e:#}"),
        })
        .write_to(&mut stream);
    }
    result
}

// ---------------------------------------------------------------------
// Hierarchical fan-in tree: the `vfl-sa leaf` relay process
// ---------------------------------------------------------------------

/// Events in a leaf relay's single event loop: a frame (or death) from
/// one of the shard's client sockets, or from that client's upstream
/// connection to the root.
enum LeafEvent {
    Client(u16, Frame),
    ClientGone(u16, String),
    Root(u16, Frame),
    RootGone(u16),
}

/// Run one leaf aggregator as a cross-process relay (`vfl-sa leaf`).
///
/// The leaf owns the contiguous client shard `[start, end)`: it binds
/// `listen`, accepts exactly those clients' joins, and opens one
/// upstream connection per shard member to the root at `connect`
/// (`Hello { client: i }` each), so the topology is invisible to both
/// ends — clients speak the ordinary `join` protocol to the leaf, the
/// root serves what looks like `end - start` ordinary clients.
///
/// Per-direction behavior:
/// * **Upstream** — masked fan-in (`MaskedActivation` /
///   `MaskedGradient` / `MaskedChunk`) folds into a
///   [`LeafAggregator`]; a completed fold sends one
///   [`Msg::PartialSum`] on the lowest-numbered live uplink (which
///   socket carries it is immaterial: the partial names its own client
///   range). Everything else relays verbatim on the sender's own
///   uplink, preserving per-sender FIFO order.
/// * **Downstream** — frames relay verbatim to the owning client,
///   after sniffing relayed [`Msg::DropoutNotice`]s: a declared-dropped
///   shard member is purged from the fold (the exact-purge invariant of
///   `coordinator::topology`) and every still-complete partial is
///   re-emitted corrected.
/// * A dead client socket closes that member's uplink — the root's
///   reader sees EOF and its stall probe declares the drop, exactly as
///   if the client had joined directly.
///
/// The root's Table-2 receive counters in this deployment reflect the
/// reduced fan-in — O((n/L)·d) masked words stay on each leaf's
/// downlink and only O(L·d) partial-sum words reach the root. That is
/// the measured win; bit-identical Table-2 parity with a flat run is
/// the in-process [`TreeAggregator`](crate::coordinator::TreeAggregator)
/// deployment's property, where client↔aggregator wire traffic is
/// unchanged. Reports (losses, accuracy) are bit-identical in both.
///
/// Known limitation: the root diagnoses a silent-but-connected client
/// behind a leaf at shard granularity (it cannot see which member's
/// tensor never completed the fold); timeout-based dropout declaration
/// itself is unaffected.
#[allow(clippy::too_many_arguments)]
pub fn leaf(
    listen: &str,
    connect: &str,
    index: usize,
    start: u16,
    end: u16,
    stream: &StreamCfg,
    revocable: bool,
) -> Result<()> {
    let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
    leaf_on(listener, connect, index, start, end, stream, revocable)
}

/// [`leaf`] on an already-bound listener (lets tests bind port 0 and
/// learn the real port before clients race to connect).
#[allow(clippy::too_many_arguments)]
pub fn leaf_on(
    listener: TcpListener,
    connect: &str,
    index: usize,
    start: u16,
    end: u16,
    stream: &StreamCfg,
    revocable: bool,
) -> Result<()> {
    let listen = listener.local_addr().map(|a| a.to_string()).unwrap_or_default();
    let members: Vec<u16> = (start..end).collect();
    eprintln!(
        "leaf {index}: listening on {listen} for clients {start}..{end}, root at {connect}"
    );

    let (tx, rx) = channel::<LeafEvent>();
    let mut down: BTreeMap<u16, TcpStream> = BTreeMap::new();
    while down.len() < members.len() {
        let (sock, peer) = listener.accept().context("accept")?;
        sock.set_nodelay(true).ok();
        sock.set_write_timeout(Some(DEFAULT_WRITE_TIMEOUT)).ok();
        let mut reader = sock.try_clone().context("clone stream")?;
        let hello = Frame::read_from(&mut reader)?;
        let Frame::Hello { client } = hello else { bail!("expected Hello, got {hello:?}") };
        if !(start..end).contains(&client) {
            bail!("client {client} joined the wrong leaf (this one owns {start}..{end})");
        }
        if down.contains_key(&client) {
            bail!("client {client} connected twice");
        }
        eprintln!("leaf {index}: client {client} joined from {peer}");
        down.insert(client, sock);
        let tx = tx.clone();
        thread::spawn(move || loop {
            match Frame::read_from(&mut reader) {
                Ok(f) => {
                    if tx.send(LeafEvent::Client(client, f)).is_err() {
                        break;
                    }
                }
                Err(e) => {
                    let _ = tx.send(LeafEvent::ClientGone(client, format!("{e:#}")));
                    break;
                }
            }
        });
    }

    // one upstream connection per shard member — the root's accept
    // loop sees ordinary client joins
    let mut up: BTreeMap<u16, TcpStream> = BTreeMap::new();
    for &c in &members {
        let mut sock =
            TcpStream::connect(connect).with_context(|| format!("connect {connect}"))?;
        sock.set_nodelay(true).ok();
        sock.set_write_timeout(Some(DEFAULT_WRITE_TIMEOUT)).ok();
        write_frame(&mut sock, &Frame::Hello { client: c })?;
        let mut reader = sock.try_clone().context("clone stream")?;
        let tx = tx.clone();
        thread::spawn(move || loop {
            match Frame::read_from(&mut reader) {
                Ok(f) => {
                    if tx.send(LeafEvent::Root(c, f)).is_err() {
                        break;
                    }
                }
                Err(_) => {
                    let _ = tx.send(LeafEvent::RootGone(c));
                    break;
                }
            }
        });
        up.insert(c, sock);
    }
    drop(tx);

    // the fold itself: the same LeafAggregator the in-process tree
    // runs, with its own worker pool on a chunked multi-worker config
    let pool = if stream.chunk_words.is_some() && stream.agg_workers > 1 {
        Some(crate::coordinator::streaming::WorkerPool::new(
            stream.agg_workers.min(stream.shards.max(1)),
        ))
    } else {
        None
    };
    let mut fold =
        LeafAggregator::new(index, start, end, stream, revocable, pool.as_ref().map(|p| p.client()));

    let mut stopped: BTreeSet<u16> = BTreeSet::new();
    // run until every shard member was stopped by the root or lost
    while !members.iter().all(|m| stopped.contains(m) || !down.contains_key(m)) {
        let ev = rx.recv().context("leaf event channel closed")?;
        match ev {
            LeafEvent::Client(c, Frame::Msg { bytes }) => {
                let emission = match Msg::decode(&bytes)? {
                    Msg::MaskedActivation { round, from, words } => {
                        fold.on_masked(round, TAG_ACTIVATION as u8, from, words)?
                    }
                    Msg::MaskedGradient { round, from, words } => {
                        fold.on_masked(round, TAG_GRADIENT as u8, from, words)?
                    }
                    Msg::MaskedChunk { round, from, tag, shard, offset, total, words } => {
                        fold.on_chunk(round, tag, from, shard, offset, total, &words)?
                    }
                    // non-fan-in protocol traffic relays verbatim on
                    // the sender's own uplink (per-sender FIFO)
                    _ => {
                        if let Some(w) = up.get_mut(&c) {
                            if let Err(e) = write_msg_frame(w, &bytes) {
                                eprintln!("leaf {index}: uplink {c} write failed ({e:#})");
                                up.remove(&c);
                            }
                        }
                        None
                    }
                };
                if let Some(m) = emission {
                    send_partial(index, &mut up, &m)?;
                }
            }
            LeafEvent::Client(c, Frame::Note(n)) => {
                if let Some(w) = up.get_mut(&c) {
                    if let Err(e) = write_frame(w, &Frame::Note(n)) {
                        eprintln!("leaf {index}: uplink {c} write failed ({e:#})");
                        up.remove(&c);
                    }
                }
            }
            LeafEvent::Client(c, f) => bail!("unexpected frame from client {c}: {f:?}"),
            LeafEvent::ClientGone(c, e) => {
                eprintln!("leaf {index}: client {c} disconnected ({e}), closing its uplink");
                down.remove(&c);
                // dropping the uplink is how the root learns: its
                // reader sees EOF and the stall probe declares the
                // drop; the DropoutNotice then comes back through the
                // sniffer below, which purges the fold
                up.remove(&c);
            }
            LeafEvent::Root(c, Frame::Msg { bytes }) => {
                // sniff recovery declarations before relaying: a
                // declared-dropped shard member must leave the fold,
                // and every still-complete partial go up corrected
                if let Msg::DropoutNotice { ref dropped, .. } = Msg::decode(&bytes)? {
                    for &d in dropped {
                        if (start..end).contains(&d) {
                            for m in fold.purge(d)? {
                                send_partial(index, &mut up, &m)?;
                            }
                        }
                    }
                }
                if let Some(w) = down.get_mut(&c) {
                    if write_msg_frame(w, &bytes).is_err() {
                        down.remove(&c);
                        up.remove(&c);
                    }
                }
            }
            LeafEvent::Root(c, Frame::Stop) => {
                if let Some(w) = down.get_mut(&c) {
                    let _ = Frame::Stop.write_to(w);
                }
                stopped.insert(c);
            }
            LeafEvent::Root(c, f) => {
                // round boundaries and any other control frame relay
                // verbatim to the owning client
                if let Some(w) = down.get_mut(&c) {
                    if write_frame(w, &f).is_err() {
                        down.remove(&c);
                        up.remove(&c);
                    }
                }
            }
            LeafEvent::RootGone(c) => {
                if !stopped.contains(&c) {
                    bail!("leaf {index}: root connection for client {c} lost");
                }
            }
        }
    }
    eprintln!("leaf {index}: run complete");
    Ok(())
}

/// Forward a folded partial on the lowest-numbered live uplink,
/// falling through to the next on a write failure so a half-dead
/// shard keeps progressing.
fn send_partial(index: usize, up: &mut BTreeMap<u16, TcpStream>, m: &Msg) -> Result<()> {
    let bytes = m.encode();
    let ids: Vec<u16> = up.keys().copied().collect();
    for c in ids {
        let Some(w) = up.get_mut(&c) else { continue };
        match write_msg_frame(w, &bytes) {
            Ok(()) => return Ok(()),
            Err(e) => {
                eprintln!("leaf {index}: uplink {c} write failed ({e:#}), trying the next");
                up.remove(&c);
            }
        }
    }
    bail!("leaf {index}: no live uplink left to carry a partial sum")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A writer that always reports one error kind — the blocking
    /// socket whose write timeout just fired, or a plain failure.
    struct Stall(std::io::ErrorKind);

    impl Write for Stall {
        fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
            Err(std::io::Error::new(self.0, "stalled"))
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn stalled_write_surfaces_the_typed_error() {
        // both kinds the platforms use for an expired SO_SNDTIMEO
        for kind in [std::io::ErrorKind::WouldBlock, std::io::ErrorKind::TimedOut] {
            let err = write_frame(&mut Stall(kind), &Frame::Stop).unwrap_err();
            let st = err.downcast_ref::<WriteStalled>().expect("typed WriteStalled");
            assert_eq!(st.timeout, DEFAULT_WRITE_TIMEOUT);
            // the zero-copy msg-frame path maps the same way
            let err = write_msg_frame(&mut Stall(kind), &[1, 2, 3]).unwrap_err();
            assert!(err.downcast_ref::<WriteStalled>().is_some());
        }
        // an ordinary write failure stays untyped
        let err =
            write_frame(&mut Stall(std::io::ErrorKind::BrokenPipe), &Frame::Stop).unwrap_err();
        assert!(err.downcast_ref::<WriteStalled>().is_none());
    }
}

fn client_loop(party: &mut dyn Party, stream: &mut TcpStream) -> Result<()> {
    loop {
        let frame = Frame::read_from(stream)?;
        let mut ob = Outbox::default();
        match frame {
            Frame::Stop => return Ok(()),
            Frame::Round(spec) => party.on_round_start(&spec, &mut ob)?,
            Frame::Msg { bytes } => {
                let msg = Msg::decode(&bytes)?;
                party.on_message(Addr::Aggregator, msg, &mut ob)?;
            }
            f => bail!("unexpected frame {f:?}"),
        }
        for (to, msg) in ob.msgs {
            if to != Addr::Aggregator {
                bail!("clients may only address the aggregator");
            }
            write_msg_frame(stream, &msg.into_bytes())?;
        }
        for n in ob.notes {
            write_frame(stream, &Frame::Note(n))?;
        }
    }
}
