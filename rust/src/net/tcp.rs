//! Cross-process transport: the aggregator (plus driver) serves TCP,
//! every client party joins over a socket — `vfl-sa serve` / `vfl-sa
//! join` in `main.rs`.
//!
//! The star topology maps one-to-one onto sockets: each client holds a
//! single connection to the server, which relays nothing client-to-
//! client (the §4 protocol never needs it). Round-boundary controls
//! and driver notes ride the same connection as [`Frame`]s. The server
//! meters the *inner* protocol-message encodings through a [`Network`],
//! so a socket run reports the same Table-2 byte counters as the
//! simulator; framing overhead is transport cost and deliberately
//! uncounted.
//!
//! Every process builds the same deterministic synthetic dataset from
//! the shared `RunConfig` seed, so no raw features ever cross a
//! socket that wouldn't in the simulated protocol.

use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::thread;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::messages::Msg;
use crate::coordinator::party::{Note, Outbox, Party, RoundSpec};
use crate::coordinator::Metrics;

use super::frame::Frame;
use super::{Addr, Network};

/// What a completed `serve` run hands back.
pub struct ServeOutcome {
    /// Driver notes: losses, predictions, round completions.
    pub notes: Vec<Note>,
    /// Table-2 byte counters, metered server-side (every protocol
    /// message crosses the aggregator in a star topology).
    pub net: Network,
    /// The aggregator's CPU meters (clients report their own locally).
    pub metrics: Metrics,
}

enum Event {
    Frame(usize, Frame),
    Gone(usize, String),
}

/// Route an aggregator outbox to the client sockets, metering each
/// protocol message.
fn route_server(
    net: &mut Network,
    writers: &mut [TcpStream],
    ob: Outbox,
    notes: &mut Vec<Note>,
) -> Result<()> {
    for (to, msg) in ob.msgs {
        let Addr::Client(ci) = to else { bail!("aggregator addressed itself") };
        let bytes = msg.encode();
        net.meter(Addr::Aggregator, to, bytes.len());
        Frame::Msg { bytes }.write_to(&mut writers[ci])?;
    }
    notes.extend(ob.notes);
    Ok(())
}

/// Host the aggregator: accept `n_clients` joins, run the schedule,
/// return the run's notes and byte counters.
pub fn serve(
    listen: &str,
    mut aggregator: Box<dyn Party + '_>,
    schedule: &[RoundSpec],
    n_clients: usize,
) -> Result<ServeOutcome> {
    let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
    eprintln!("serve: listening on {listen}, waiting for {n_clients} client(s)");

    let (tx, rx) = channel::<Event>();
    let mut writers: Vec<Option<TcpStream>> = (0..n_clients).map(|_| None).collect();
    let mut connected = 0usize;
    while connected < n_clients {
        let (stream, peer) = listener.accept().context("accept")?;
        stream.set_nodelay(true).ok();
        let mut reader = stream.try_clone().context("clone stream")?;
        let hello = Frame::read_from(&mut reader)?;
        let Frame::Hello { client } = hello else { bail!("expected Hello, got {hello:?}") };
        let ci = client as usize;
        if ci >= n_clients {
            bail!("client index {ci} out of range (need 0..{n_clients})");
        }
        if writers[ci].is_some() {
            bail!("client {ci} connected twice");
        }
        eprintln!("serve: client {ci} joined from {peer}");
        writers[ci] = Some(stream);
        let tx = tx.clone();
        thread::spawn(move || loop {
            match Frame::read_from(&mut reader) {
                Ok(f) => {
                    if tx.send(Event::Frame(ci, f)).is_err() {
                        break;
                    }
                }
                Err(e) => {
                    let _ = tx.send(Event::Gone(ci, format!("{e:#}")));
                    break;
                }
            }
        });
        connected += 1;
    }
    drop(tx);
    let mut writers: Vec<TcpStream> =
        writers.into_iter().map(|w| w.expect("all clients connected")).collect();

    let mut net = Network::new(n_clients);
    let mut notes: Vec<Note> = Vec::new();
    for spec in schedule {
        net.phase = spec.phase;
        // boundary first, on every socket, so each client orders the
        // round ahead of its first protocol message. Only the active
        // party (client 0) receives the batch ids: shipping them to a
        // passive would leak exactly the batch membership the sealed-ID
        // broadcast (§4.0.2) exists to hide.
        for (ci, w) in writers.iter_mut().enumerate() {
            let for_client = if ci == 0 {
                spec.clone()
            } else {
                RoundSpec { ids: Vec::new(), ..spec.clone() }
            };
            Frame::Round(for_client).write_to(w)?;
        }
        let mut ob = Outbox::default();
        aggregator.on_round_start(spec, &mut ob)?;
        route_server(&mut net, &mut writers, ob, &mut notes)?;
        loop {
            match rx.recv().map_err(|_| anyhow!("all client connections lost"))? {
                Event::Gone(ci, e) => bail!("client {ci} disconnected: {e}"),
                Event::Frame(ci, Frame::Msg { bytes }) => {
                    net.meter(Addr::Client(ci), Addr::Aggregator, bytes.len());
                    let msg = Msg::decode(&bytes)?;
                    let mut ob = Outbox::default();
                    aggregator.on_message(Addr::Client(ci), msg, &mut ob)?;
                    route_server(&mut net, &mut writers, ob, &mut notes)?;
                }
                Event::Frame(_, Frame::Note(n)) => match n {
                    Note::RoundDone { round } if round == spec.round => {
                        notes.push(Note::RoundDone { round });
                        break;
                    }
                    Note::Failed { who, error } => bail!("party {who} failed: {error}"),
                    other => notes.push(other),
                },
                Event::Frame(ci, f) => bail!("unexpected frame from client {ci}: {f:?}"),
            }
        }
    }
    for w in writers.iter_mut() {
        let _ = Frame::Stop.write_to(w);
    }
    Ok(ServeOutcome { notes, net, metrics: aggregator.take_metrics() })
}

/// Run one client party against a serving aggregator. Returns the
/// party's CPU meters once the server signals Stop.
pub fn join(connect: &str, client: usize, mut party: Box<dyn Party + '_>) -> Result<Metrics> {
    let mut stream = TcpStream::connect(connect).with_context(|| format!("connect {connect}"))?;
    stream.set_nodelay(true).ok();
    Frame::Hello { client: client as u16 }.write_to(&mut stream)?;
    eprintln!("join: client {client} connected to {connect}");

    let result = client_loop(&mut *party, &mut stream);
    if let Err(e) = &result {
        // best-effort: surface the failure to the server before dying
        let _ = Frame::Note(Note::Failed {
            who: (client + 1) as u16,
            error: format!("{e:#}"),
        })
        .write_to(&mut stream);
    }
    result?;
    Ok(party.take_metrics())
}

fn client_loop(party: &mut dyn Party, stream: &mut TcpStream) -> Result<()> {
    loop {
        let frame = Frame::read_from(stream)?;
        let mut ob = Outbox::default();
        match frame {
            Frame::Stop => return Ok(()),
            Frame::Round(spec) => party.on_round_start(&spec, &mut ob)?,
            Frame::Msg { bytes } => {
                let msg = Msg::decode(&bytes)?;
                party.on_message(Addr::Aggregator, msg, &mut ob)?;
            }
            f => bail!("unexpected frame {f:?}"),
        }
        for (to, msg) in ob.msgs {
            if to != Addr::Aggregator {
                bail!("clients may only address the aggregator");
            }
            Frame::Msg { bytes: msg.encode() }.write_to(stream)?;
        }
        for n in ob.notes {
            Frame::Note(n).write_to(stream)?;
        }
    }
}
