//! Length-prefixed socket framing for the TCP transport.
//!
//! A frame is `u32 length ‖ u8 kind ‖ payload`, little-endian, written
//! atomically per frame. Protocol [`Msg`]s stay opaque bytes here —
//! the Table-2 byte counters meter the *inner* message encoding, so a
//! TCP run meters identically to a simulated one (framing overhead is
//! transport cost, not protocol cost).

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::coordinator::party::{Note, RoundSpec};
use crate::net::wire::{Reader, Writer};

/// Everything that crosses a serve/join socket.
#[derive(Debug, PartialEq)]
pub enum Frame {
    /// Client → server greeting: which client index this socket is.
    Hello { client: u16 },
    /// Server → client round boundary.
    Round(RoundSpec),
    /// A serialized protocol [`Msg`](crate::coordinator::messages::Msg).
    Msg { bytes: Vec<u8> },
    /// Client → server driver note.
    Note(Note),
    /// Server → client orderly shutdown.
    Stop,
}

const F_HELLO: u8 = 1;
const F_ROUND: u8 = 2;
const F_MSG: u8 = 3;
const F_NOTE: u8 = 4;
const F_STOP: u8 = 5;

/// Cap a frame at 256 MiB — far above any legitimate message, low
/// enough to reject garbage lengths before allocating. Enforced on
/// *both* sides of the socket: the writer refuses to emit an oversize
/// body (the old `body.len() as u32` cast silently truncated it,
/// desynchronizing the stream), and the reader refuses to trust a
/// corrupt 4-byte length field that would otherwise allocate up to
/// 4 GiB.
pub const MAX_FRAME_LEN: u32 = 256 << 20;

/// Typed error for a frame body beyond [`MAX_FRAME_LEN`], on either
/// side of the socket. Callers can downcast an `anyhow::Error` to this
/// to distinguish "peer sent garbage" from transport failures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameTooLong {
    /// The offending body length in bytes.
    pub len: u64,
    /// The enforced cap ([`MAX_FRAME_LEN`]).
    pub max: u32,
}

impl std::fmt::Display for FrameTooLong {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "frame length {} exceeds the {}-byte cap", self.len, self.max)
    }
}

impl std::error::Error for FrameTooLong {}

/// The shared cap check: used by the write path (before the `u32`
/// length cast can truncate) and the read path (before the length
/// prefix is trusted with an allocation).
fn check_frame_len(len: u64) -> Result<()> {
    if len > MAX_FRAME_LEN as u64 {
        bail!(FrameTooLong { len, max: MAX_FRAME_LEN });
    }
    Ok(())
}

impl Frame {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Frame::Hello { client } => {
                w.u8(F_HELLO);
                w.u16(*client);
            }
            Frame::Round(spec) => {
                w.u8(F_ROUND);
                spec.encode_into(&mut w);
            }
            Frame::Msg { bytes } => {
                w.u8(F_MSG);
                w.bytes(bytes);
            }
            Frame::Note(n) => {
                w.u8(F_NOTE);
                n.encode_into(&mut w);
            }
            Frame::Stop => w.u8(F_STOP),
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Frame> {
        let mut r = Reader::new(buf);
        let f = match r.u8()? {
            F_HELLO => Frame::Hello { client: r.u16()? },
            F_ROUND => Frame::Round(RoundSpec::decode_from(&mut r)?),
            F_MSG => Frame::Msg { bytes: r.bytes()? },
            F_NOTE => Frame::Note(Note::decode_from(&mut r)?),
            F_STOP => Frame::Stop,
            t => bail!("unknown frame kind {t}"),
        };
        if !r.done() {
            bail!("trailing bytes in frame ({} left)", r.remaining());
        }
        Ok(f)
    }

    /// Write one length-prefixed frame. An oversize body is a typed
    /// error ([`FrameTooLong`]) — never a silently truncated length
    /// prefix.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let body = self.encode();
        check_frame_len(body.len() as u64)?;
        w.write_all(&(body.len() as u32).to_le_bytes()).context("frame length")?;
        w.write_all(&body).context("frame body")?;
        w.flush().context("frame flush")?;
        Ok(())
    }

    /// Read one length-prefixed frame (blocking). A length prefix
    /// beyond [`MAX_FRAME_LEN`] is a typed error ([`FrameTooLong`]),
    /// rejected before any allocation.
    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let mut len = [0u8; 4];
        r.read_exact(&mut len).context("frame length")?;
        let len = u32::from_le_bytes(len);
        check_frame_len(len as u64)?;
        let mut body = vec![0u8; len as usize];
        r.read_exact(&mut body).context("frame body")?;
        Frame::decode(&body)
    }
}

/// The 9 wire bytes that precede a `Msg` frame's message bytes:
/// `u32 frame_len ‖ F_MSG ‖ u32 msg_len`. Factored out so the
/// zero-copy senders (tcp's `write_msg_frame`, the evloop out-queue)
/// can emit header and message body from separate buffers while
/// staying bit-identical to `Frame::Msg { bytes }.write_to(..)`.
/// Oversize bodies get the same typed [`FrameTooLong`] as `write_to`.
pub fn msg_frame_header(msg_len: usize) -> Result<[u8; 9]> {
    let body_len = 1 + 4 + msg_len as u64;
    check_frame_len(body_len)?;
    let mut h = [0u8; 9];
    h[..4].copy_from_slice(&(body_len as u32).to_le_bytes());
    h[4] = F_MSG;
    h[5..9].copy_from_slice(&(msg_len as u32).to_le_bytes());
    Ok(h)
}

/// One fully-framed `Msg` as a single exact-capacity buffer —
/// bit-identical to what `Frame::Msg { bytes }.write_to(..)` would put
/// on the socket. Used where a pre-assembled wire buffer is queued
/// rather than written (the evloop outbound queue).
pub fn encode_msg_frame(msg_bytes: &[u8]) -> Result<Vec<u8>> {
    let h = msg_frame_header(msg_bytes.len())?;
    let mut wire = Vec::with_capacity(h.len() + msg_bytes.len());
    wire.extend_from_slice(&h);
    wire.extend_from_slice(msg_bytes);
    Ok(wire)
}

/// Write one `Msg` frame from pre-encoded message bytes: the 9-byte
/// header then the body, no intermediate frame-body `Vec` (the
/// zero-copy twin of `Frame::Msg { .. }.write_to`, same byte stream
/// and the same error contexts).
pub fn write_msg_to(w: &mut impl Write, msg_bytes: &[u8]) -> Result<()> {
    let h = msg_frame_header(msg_bytes.len())?;
    w.write_all(&h).context("frame length")?;
    w.write_all(msg_bytes).context("frame body")?;
    w.flush().context("frame flush")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::party::RoundKind;
    use crate::net::Phase;

    #[test]
    fn frames_roundtrip() {
        let frames = [
            Frame::Hello { client: 3 },
            Frame::Round(RoundSpec {
                round: 5,
                kind: RoundKind::Test,
                rotate: false,
                phase: Phase::Testing,
                ids: vec![9, 8, 7],
            }),
            Frame::Msg { bytes: vec![1, 2, 3, 4] },
            Frame::Note(Note::Loss { round: 2, loss: 1.5 }),
            Frame::Stop,
        ];
        for f in frames {
            let mut buf = Vec::new();
            f.write_to(&mut buf).unwrap();
            let mut cur = std::io::Cursor::new(buf);
            assert_eq!(Frame::read_from(&mut cur).unwrap(), f);
        }
    }

    #[test]
    fn truncated_frame_rejected() {
        let mut buf = Vec::new();
        Frame::Stop.write_to(&mut buf).unwrap();
        buf.pop();
        let mut cur = std::io::Cursor::new(buf);
        assert!(Frame::read_from(&mut cur).is_err());
    }

    #[test]
    fn oversized_length_rejected_with_typed_error_before_allocating() {
        let mut buf = (u32::MAX).to_le_bytes().to_vec();
        buf.extend_from_slice(&[0; 8]);
        let mut cur = std::io::Cursor::new(buf);
        let err = Frame::read_from(&mut cur).unwrap_err();
        let too_long = err.downcast_ref::<FrameTooLong>().expect("typed frame-length error");
        assert_eq!(*too_long, FrameTooLong { len: u32::MAX as u64, max: MAX_FRAME_LEN });
    }

    #[test]
    fn zero_copy_msg_frame_paths_are_bit_identical() {
        // header-then-body writers must reproduce Frame::Msg.write_to
        // byte for byte — the frame-encode rule of the zero-copy path
        for len in [0usize, 1, 4, 100, 70_000] {
            let bytes: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
            let mut want = Vec::new();
            Frame::Msg { bytes: bytes.clone() }.write_to(&mut want).unwrap();
            let mut via_write = Vec::new();
            write_msg_to(&mut via_write, &bytes).unwrap();
            assert_eq!(via_write, want, "write_msg_to len={len}");
            assert_eq!(encode_msg_frame(&bytes).unwrap(), want, "encode_msg_frame len={len}");
            let h = msg_frame_header(bytes.len()).unwrap();
            assert_eq!(&want[..9], &h[..], "msg_frame_header len={len}");
        }
    }

    #[test]
    fn zero_copy_msg_frame_enforces_length_cap() {
        // msg_len such that 5 + msg_len > MAX_FRAME_LEN must be the
        // same typed error write_to raises — checked without
        // allocating a 256 MiB body
        let err = msg_frame_header(MAX_FRAME_LEN as usize).unwrap_err();
        let too_long = err.downcast_ref::<FrameTooLong>().expect("typed frame-length error");
        assert_eq!(too_long.max, MAX_FRAME_LEN);
        assert!(msg_frame_header(MAX_FRAME_LEN as usize - 5).is_ok());
    }

    #[test]
    fn frame_len_cap_enforced_on_both_sides() {
        // the boundary itself is legal...
        assert!(check_frame_len(MAX_FRAME_LEN as u64).is_ok());
        // ...one byte past it is the typed error (the same check guards
        // write_to before its u32 cast and read_from before its alloc)
        let err = check_frame_len(MAX_FRAME_LEN as u64 + 1).unwrap_err();
        assert!(err.downcast_ref::<FrameTooLong>().is_some());
        // a would-have-truncated 4 GiB body is caught, not wrapped to 0
        let err = check_frame_len(1 << 32).unwrap_err();
        assert_eq!(
            err.downcast_ref::<FrameTooLong>(),
            Some(&FrameTooLong { len: 1 << 32, max: MAX_FRAME_LEN })
        );
    }
}
