//! Multi-threaded transport: every party runs on its own OS thread and
//! exchanges serialized messages over channels — the same §4 state
//! machines the simulator drives, now genuinely concurrent.
//!
//! Topology and ordering guarantees
//! --------------------------------
//! The paper's star topology is load-bearing here: clients only ever
//! talk to the aggregator, so each client's inbox has exactly one
//! producer (the aggregator thread) and per-sender FIFO holds
//! trivially. Round-start controls are routed *through* the aggregator
//! thread for the same reason — the aggregator forwards the control to
//! every client before acting on it itself, which orders each round's
//! control ahead of that round's first protocol message on every
//! channel. The aggregator's own inbox is multi-producer, but the §4
//! machines only rely on per-sender ordering (fan-ins are buffered by
//! sender id), so arbitrary interleaving across clients is safe.
//!
//! Bytes are metered through the shared [`Network`] exactly as the
//! simulator meters them, and the driver schedules rounds through the
//! same windowed [`RoundWindow`] (`--rounds-in-flight`; width 1 is the
//! strictly serial pre-pipeline behavior) keyed on the active party's
//! `RoundDone` notes — which is why a threaded run produces
//! bit-identical reports and Table-2 counters to a simulated one at
//! every window width (asserted by `tests/transport_equivalence.rs`
//! and `tests/round_pipeline.rs`).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::messages::Msg;
use crate::coordinator::party::{Note, Outbox, Party, RoundSpec};
use crate::coordinator::window::RoundWindow;
use crate::coordinator::Metrics;

use super::transport::{
    harvest, node_of_addr, StallClock, Transport, TransportOutcome, DEFAULT_STALL_CAP,
    DEFAULT_STALL_TIMEOUT, MAX_IDLE_PROBES,
};
use super::{Addr, Network};

/// What flows over a party's inbox channel.
enum Envelope {
    /// Round boundary (driver → aggregator → everyone).
    Round(RoundSpec),
    /// A serialized protocol message.
    Msg { from: Addr, bytes: Vec<u8> },
    /// Quiescence probe (driver → aggregator only): no note arrived for
    /// the stall timeout — check for dropped peers.
    Stall,
    /// Driver bookkeeping (driver → aggregator only): the scheduler
    /// observed this round's `RoundDone` ([`Party::on_round_complete`]).
    Completed(u32),
    /// Orderly shutdown.
    Stop,
}

/// Where a party's outgoing traffic goes.
enum Router {
    /// The aggregator addresses any client directly.
    Aggregator { clients: Vec<Sender<Envelope>> },
    /// Clients only ever address the aggregator.
    Client { agg: Sender<Envelope> },
}

impl Router {
    fn send(&self, from: Addr, to: Addr, bytes: Vec<u8>) -> Result<()> {
        let tx = match (self, to) {
            (Router::Aggregator { clients }, Addr::Client(i)) => {
                clients.get(i).ok_or_else(|| anyhow!("client {i} out of range"))?
            }
            (Router::Client { agg }, Addr::Aggregator) => agg,
            _ => bail!("invalid route {from:?} → {to:?} (star topology)"),
        };
        tx.send(Envelope::Msg { from, bytes }).map_err(|_| anyhow!("peer channel closed"))
    }
}

/// One party's event loop: receive, react, route, repeat.
fn run_party(
    party: &mut dyn Party,
    rx: &Receiver<Envelope>,
    router: &Router,
    note_tx: &Sender<Note>,
    net: &Arc<Mutex<Network>>,
) -> Result<()> {
    let me = party.addr();
    // events handled since the last quiescence probe: lets the driver
    // tell "busy, keep waiting" apart from "dead, give up"
    let mut processed_since_probe = 0u64;
    loop {
        // a closed inbox means every producer is gone: exit quietly
        let Ok(env) = rx.recv() else { break };
        let mut ob = Outbox::default();
        match env {
            Envelope::Stop => {
                if let Router::Aggregator { clients } = router {
                    for c in clients {
                        let _ = c.send(Envelope::Stop);
                    }
                }
                break;
            }
            Envelope::Round(spec) => {
                // forward the boundary before acting on it, so every
                // client channel sees Round(k) ahead of any round-k
                // protocol message
                if let Router::Aggregator { clients } = router {
                    for c in clients {
                        c.send(Envelope::Round(spec.clone()))
                            .map_err(|_| anyhow!("client channel closed"))?;
                    }
                }
                processed_since_probe += 1;
                party.on_round_start(&spec, &mut ob)?;
            }
            Envelope::Msg { from, bytes } => {
                let msg = Msg::decode(&bytes)?;
                processed_since_probe += 1;
                party.on_message(from, msg, &mut ob)?;
            }
            Envelope::Stall => {
                // only probe when truly quiescent: if events were
                // handled since the last probe the timeout was stale
                // (e.g. the probe queued behind a burst of messages),
                // and declaring dropouts from a half-filled fan-in
                // would be a false positive
                if processed_since_probe == 0 {
                    party.on_stall(&mut ob)?;
                }
                let acted = !ob.msgs.is_empty() || !ob.notes.is_empty();
                ob.notes.push(Note::Stall { acted, processed: processed_since_probe });
                processed_since_probe = 0;
            }
            Envelope::Completed(round) => {
                // scheduler bookkeeping, not protocol activity: it
                // neither counts toward the probe suppression nor is
                // forwarded to the clients
                party.on_round_complete(round);
            }
        }
        for (to, msg) in ob.msgs {
            let bytes = msg.into_bytes();
            net.lock().unwrap().meter(me, to, bytes.len());
            router.send(me, to, bytes)?;
        }
        for n in ob.notes {
            note_tx.send(n).map_err(|_| anyhow!("driver gone"))?;
        }
    }
    Ok(())
}

/// One thread per party, channels for transport, rounds scheduled by
/// the shared [`RoundWindow`] on the active party's `RoundDone` notes
/// (up to `--rounds-in-flight` rounds pipelined).
///
/// Dropout detection is timeout-based and *adaptive*: when no note
/// arrives for the current [`StallClock`] window — the floor stretched
/// by an EWMA of the observed inter-note gaps, up to a cap — the
/// driver sends the aggregator a quiescence probe
/// ([`Party::on_stall`]). A probe that finds recovery work resets the
/// clock; [`MAX_IDLE_PROBES`] consecutive probes with no work and no
/// traffic abort the run as genuinely stalled.
pub struct ThreadedTransport {
    n_clients: usize,
    stall_floor: Duration,
    stall_cap: Duration,
}

impl ThreadedTransport {
    pub fn new(n_clients: usize) -> Self {
        ThreadedTransport {
            n_clients,
            stall_floor: DEFAULT_STALL_TIMEOUT,
            stall_cap: DEFAULT_STALL_CAP,
        }
    }

    /// Override the dropout-detection floor (reachable from
    /// `RunConfig::stall_timeout_ms`; tests shrink it so declared
    /// dropouts don't sleep through full default windows).
    pub fn with_stall_timeout(mut self, stall_timeout: Duration) -> Self {
        self.stall_floor = stall_timeout;
        self
    }

    /// Override the adaptive window's cap (reachable from
    /// `RunConfig::stall_cap_ms`).
    pub fn with_stall_cap(mut self, cap: Duration) -> Self {
        self.stall_cap = cap;
        self
    }
}

impl Transport for ThreadedTransport {
    fn execute<'e>(
        &mut self,
        parties: Vec<Box<dyn Party + 'e>>,
        schedule: &[RoundSpec],
        window: usize,
    ) -> Result<TransportOutcome> {
        assert_eq!(parties.len(), self.n_clients + 1, "aggregator + clients");
        // enforce the `unsafe impl Sync for Engine` contract at the
        // boundary where concurrency actually starts: parties holding
        // an unaudited shared engine must not run on sibling threads
        if parties.iter().any(|p| !p.concurrent_safe()) {
            bail!(
                "the threaded transport requires the reference backend \
                 (a shared PJRT engine is not audited for concurrent use)"
            );
        }
        let net = Arc::new(Mutex::new(Network::new(self.n_clients)));
        let (note_tx, note_rx) = channel::<Note>();

        // one inbox per party; the driver keeps only the aggregator's
        // sender, and each client thread keeps only the aggregator's —
        // so a dead aggregator closes every client inbox (no hangs)
        let mut inboxes: Vec<(Sender<Envelope>, Receiver<Envelope>)> =
            (0..parties.len()).map(|_| channel()).collect();
        let agg_tx = inboxes[0].0.clone();
        let client_txs: Vec<Sender<Envelope>> =
            inboxes.iter().skip(1).map(|(tx, _)| tx.clone()).collect();

        let outcome = thread::scope(|s| -> Result<TransportOutcome> {
            let mut handles = Vec::with_capacity(parties.len());
            for (idx, mut party) in parties.into_iter().enumerate() {
                let rx = inboxes.remove(0).1; // consume in order
                let router = if idx == 0 {
                    Router::Aggregator { clients: client_txs.clone() }
                } else {
                    Router::Client { agg: agg_tx.clone() }
                };
                let note_tx = note_tx.clone();
                let net = Arc::clone(&net);
                handles.push(s.spawn(move || {
                    let who = node_of_addr(party.addr()) as u16;
                    // catch panics too: an unwinding party thread must
                    // still surface a Failed note, or the driver would
                    // block on note_rx forever (siblings keep their
                    // note_tx clones alive)
                    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_party(&mut *party, &rx, &router, &note_tx, &net)
                    }));
                    let error = match run {
                        Ok(Ok(())) => None,
                        Ok(Err(e)) => Some(format!("{e:#}")),
                        Err(p) => Some(format!(
                            "panicked: {}",
                            p.downcast_ref::<&str>()
                                .map(|s| s.to_string())
                                .or_else(|| p.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "<non-string payload>".into())
                        )),
                    };
                    if let Some(error) = error {
                        let _ = note_tx.send(Note::Failed { who, error });
                    }
                    party
                }));
            }
            // the spawning loop is done with these; drop our clones so
            // channel closure semantics reflect only live threads
            drop(inboxes);
            drop(client_txs);
            drop(note_tx);

            let mut notes: Vec<Note> = Vec::new();
            let mut failure: Option<String> = None;
            let mut clock = StallClock::new(self.stall_floor, self.stall_cap);
            let mut last_note = std::time::Instant::now();
            let mut win = RoundWindow::new(schedule, window);
            let mut idle_probes = 0u32;
            'drive: while !win.done() {
                // open every round the window allows, in schedule
                // order; the boundary rides through the aggregator so
                // each client channel orders it ahead of that round's
                // first protocol message
                while let Some(spec) = win.next_start() {
                    net.lock().unwrap().phase = spec.phase;
                    if agg_tx.send(Envelope::Round(spec.clone())).is_err() {
                        failure = Some("aggregator exited early".into());
                        break 'drive;
                    }
                }
                let note = match note_rx.recv_timeout(clock.timeout()) {
                    Ok(note) => {
                        // feed the adaptive window with the real
                        // inter-note cadence of this run
                        let now = std::time::Instant::now();
                        clock.observe_gap(now - last_note);
                        last_note = now;
                        note
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        // quiescent: probe the aggregator for
                        // dropped peers; its Note::Stall reply
                        // reports whether anything moved. Reset the
                        // gap anchor so stall windows never feed
                        // the EWMA — the clock must track the run's
                        // note cadence, not its own timeouts.
                        last_note = std::time::Instant::now();
                        if agg_tx.send(Envelope::Stall).is_err() {
                            failure = Some("aggregator exited early".into());
                            break 'drive;
                        }
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        failure = Some(format!(
                            "all parties exited with round {:?} in flight",
                            win.oldest_in_flight()
                        ));
                        break 'drive;
                    }
                };
                match note {
                    Note::Failed { who, error } => {
                        failure = Some(format!("party {who} failed: {error}"));
                        break 'drive;
                    }
                    Note::Stall { acted, processed } => {
                        // transport bookkeeping, never a result note
                        if acted || processed > 0 {
                            idle_probes = 0;
                        } else {
                            idle_probes += 1;
                            if idle_probes >= MAX_IDLE_PROBES {
                                failure = Some(format!(
                                    "protocol stalled: round {} never completed",
                                    win.oldest_in_flight().unwrap_or(0)
                                ));
                                break 'drive;
                            }
                        }
                    }
                    note => {
                        // completions reset the idle-probe budget (a
                        // round boundary, like the per-round reset the
                        // serial driver had) and are relayed to the
                        // aggregator as scheduler bookkeeping
                        if matches!(note, Note::RoundDone { .. }) {
                            idle_probes = 0;
                        }
                        if let Some(n) = win.observe(note) {
                            if let Note::RoundDone { round } = &n {
                                if agg_tx.send(Envelope::Completed(*round)).is_err() {
                                    failure = Some("aggregator exited early".into());
                                    break 'drive;
                                }
                            }
                            notes.push(n);
                        }
                    }
                }
            }
            let _ = agg_tx.send(Envelope::Stop);
            drop(agg_tx);

            let mut finished: Vec<Box<dyn Party + 'e>> = Vec::with_capacity(handles.len());
            for h in handles {
                finished.push(h.join().map_err(|_| anyhow!("party thread panicked"))?);
            }
            if let Some(err) = failure {
                bail!("threaded run failed: {err}");
            }
            let net = Arc::try_unwrap(net)
                .map_err(|_| anyhow!("network still shared after join"))?
                .into_inner()
                .map_err(|_| anyhow!("network mutex poisoned"))?;
            let mut driver = Metrics::new();
            driver.record_pipeline(win.stats());
            harvest(finished, notes, net, driver)
        })?;

        Ok(outcome)
    }
}
