//! Integration coverage for the network substrate: protocol-message
//! round-trips through `net::wire`, `Network` per-(phase, party,
//! direction) byte accounting, and the socket framing.

mod common;

use common::assert_msg_roundtrip;
use vfl::coordinator::messages::{Msg, WireKeys};
use vfl::coordinator::{Note, RoundKind, RoundSpec};
use vfl::net::frame::Frame;
use vfl::net::wire::{Reader, Writer};
use vfl::net::{Addr, Network, Phase};

#[test]
fn wire_primitives_roundtrip() {
    let mut w = Writer::new();
    w.u8(250);
    w.u16(65_535);
    w.u32(1 << 30);
    w.u64(u64::MAX - 1);
    w.f32(f32::MIN_POSITIVE);
    w.bytes(&[1, 2, 3]);
    w.f32s(&[0.0, -0.0, 3.25]);
    w.u64s(&[7; 5]);
    w.fixed(&[4u8; 32]);
    let buf = w.finish();
    let mut r = Reader::new(&buf);
    assert_eq!(r.u8().unwrap(), 250);
    assert_eq!(r.u16().unwrap(), 65_535);
    assert_eq!(r.u32().unwrap(), 1 << 30);
    assert_eq!(r.u64().unwrap(), u64::MAX - 1);
    assert_eq!(r.f32().unwrap(), f32::MIN_POSITIVE);
    assert_eq!(r.bytes().unwrap(), vec![1, 2, 3]);
    assert_eq!(r.f32s().unwrap(), vec![0.0, -0.0, 3.25]);
    assert_eq!(r.u64s().unwrap(), vec![7; 5]);
    assert_eq!(r.fixed::<32>().unwrap(), [4u8; 32]);
    assert!(r.done());
}

#[test]
fn every_protocol_message_roundtrips() {
    let msgs = vec![
        Msg::RequestKeys { epoch: 9 },
        Msg::PublishKeys(WireKeys { from: 1, keys: vec![None, Some([2u8; 32])] }),
        Msg::KeyDirectory {
            epoch: 2,
            all: vec![WireKeys { from: 0, keys: vec![None, Some([1u8; 32])] }],
        },
        Msg::WeightsUpdate { round: 1, flat: vec![0.5; 16] },
        Msg::GroupWeights { round: 1, group: 2, flat: vec![-1.5; 4] },
        Msg::BatchSelect { round: 3, labels: vec![1.0, 0.0], entries: vec![vec![0xAB; 24]] },
        Msg::BatchRelay { round: 3, entries: vec![vec![0xCD; 24], vec![]] },
        Msg::PlainBatch { round: 3, labels: vec![1.0], ids: vec![1, 2, 3] },
        Msg::PlainBatchRelay { round: 3, ids: vec![u64::MAX] },
        Msg::MaskedActivation { round: 4, from: 2, words: vec![u64::MAX, 0] },
        Msg::MaskedChunk {
            round: 4,
            from: 2,
            tag: 1,
            shard: 3,
            offset: 4096,
            total: 16384,
            words: vec![u64::MAX, 0, 9],
        },
        Msg::FloatActivation { round: 4, from: 2, vals: vec![1.25, -2.5] },
        Msg::DzBroadcast { round: 4, dz: vec![0.125; 8] },
        Msg::MaskedGradient { round: 4, from: 1, words: vec![42; 3] },
        Msg::FloatGradient { round: 4, from: 1, vals: vec![0.75; 3] },
        Msg::GradientSum { round: 4, words: vec![7, 8, 9] },
        Msg::GradientChunk { round: 4, shard: 1, offset: 1296, total: 5184, words: vec![7, 8] },
        Msg::FloatGradientSum { round: 4, vals: vec![0.25] },
        Msg::Predictions { round: 5, probs: vec![0.9, 0.1] },
        Msg::SeedShares {
            epoch: 1,
            from: 2,
            commitment: [7u8; 32],
            sealed: vec![vec![], vec![0xAB; 100]],
        },
        Msg::ShareRelay { epoch: 1, sealed: vec![vec![0xCD; 100], vec![]] },
        Msg::DropoutNotice { round: 4, dropped: vec![3] },
        Msg::SurrenderShares { round: 4, from: 1, bundles: vec![(3, vec![0xEF; 84])] },
    ];
    for m in msgs {
        assert_msg_roundtrip(&m);
        // every encoding survives a Frame trip too (the TCP path)
        let enc = m.encode();
        let f = Frame::Msg { bytes: enc.clone() };
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let got = Frame::read_from(&mut std::io::Cursor::new(buf)).unwrap();
        assert_eq!(got, Frame::Msg { bytes: enc });
    }
}

#[test]
fn network_accounts_per_phase_party_direction() {
    let mut net = Network::new(3);
    net.phase = Phase::Setup;
    net.send(Addr::Aggregator, Addr::Client(0), vec![0; 11]);
    net.send(Addr::Client(0), Addr::Aggregator, vec![0; 13]);
    net.phase = Phase::Training;
    net.send(Addr::Client(1), Addr::Aggregator, vec![0; 100]);
    net.send(Addr::Aggregator, Addr::Client(2), vec![0; 50]);
    net.phase = Phase::Testing;
    net.send(Addr::Client(2), Addr::Aggregator, vec![0; 5]);

    // setup
    assert_eq!(net.sent_bytes(Addr::Aggregator, Phase::Setup), 11);
    assert_eq!(net.received_bytes(Addr::Client(0), Phase::Setup), 11);
    assert_eq!(net.sent_bytes(Addr::Client(0), Phase::Setup), 13);
    assert_eq!(net.received_bytes(Addr::Aggregator, Phase::Setup), 13);
    assert_eq!(net.transmission_bytes(Addr::Client(0), Phase::Setup), 24);
    // training isolated from setup
    assert_eq!(net.sent_bytes(Addr::Client(1), Phase::Training), 100);
    assert_eq!(net.sent_bytes(Addr::Client(1), Phase::Setup), 0);
    assert_eq!(net.received_bytes(Addr::Client(2), Phase::Training), 50);
    // testing isolated from both
    assert_eq!(net.sent_bytes(Addr::Client(2), Phase::Testing), 5);
    assert_eq!(net.transmission_bytes(Addr::Client(1), Phase::Testing), 0);
    // direction asymmetry preserved
    assert_eq!(net.sent_bytes(Addr::Client(2), Phase::Training), 0);
    assert_eq!(net.messages, 5);
}

#[test]
fn meter_matches_send_accounting() {
    // `meter` (threads/sockets) and `send` (simulation) must account
    // identically — that's what keeps Table 2 transport-independent
    let mut queued = Network::new(2);
    let mut metered = Network::new(2);
    for (net, via_send) in [(&mut queued, true), (&mut metered, false)] {
        net.phase = Phase::Training;
        for (from, to, len) in
            [(Addr::Client(0), Addr::Aggregator, 17), (Addr::Aggregator, Addr::Client(1), 23)]
        {
            if via_send {
                net.send(from, to, vec![0; len]);
            } else {
                net.meter(from, to, len);
            }
        }
    }
    for n in [Addr::Aggregator, Addr::Client(0), Addr::Client(1)] {
        assert_eq!(
            queued.transmission_bytes(n, Phase::Training),
            metered.transmission_bytes(n, Phase::Training)
        );
    }
    assert_eq!(queued.messages, metered.messages);
}

#[test]
fn control_plane_roundtrips_through_frames() {
    let spec = RoundSpec {
        round: 11,
        kind: RoundKind::Train,
        rotate: true,
        phase: Phase::Training,
        ids: (0..256).collect(),
    };
    let mut buf = Vec::new();
    Frame::Round(spec.clone()).write_to(&mut buf).unwrap();
    Frame::Note(Note::Predictions { round: 11, probs: vec![0.5; 4] }).write_to(&mut buf).unwrap();
    Frame::Stop.write_to(&mut buf).unwrap();
    let mut cur = std::io::Cursor::new(buf);
    assert_eq!(Frame::read_from(&mut cur).unwrap(), Frame::Round(spec));
    assert_eq!(
        Frame::read_from(&mut cur).unwrap(),
        Frame::Note(Note::Predictions { round: 11, probs: vec![0.5; 4] })
    );
    assert_eq!(Frame::read_from(&mut cur).unwrap(), Frame::Stop);
}
