//! The event-loop transport's own acceptance suite:
//!
//! * **Protocol equivalence.** A full secure training run through
//!   `EvloopTransport` — real localhost sockets, one readiness-driven
//!   aggregator thread — is bit-identical to the simulator, and the
//!   new connection counters prove every client was multiplexed on
//!   that one loop.
//! * **Swarm integrity.** The `vfl-sa swarm` load generator's ℤ₂⁶⁴
//!   checksum accounts for every payload frame, on the portable
//!   `poll(2)` fallback as well as the default backend.
//! * **Flat per-client memory.** Scaling the swarm 8× does not scale
//!   the peak bytes any single connection buffers: per-connection
//!   state is one partial frame + one bounded outbound queue,
//!   regardless of how many neighbours the loop carries.
//!
//! (The poller and connection state machines have their own unit
//! tests in `src/net/evloop/` — partial-frame reassembly, outbound
//! backpressure, epoll/poll parity.)
#![cfg(unix)]

mod common;

use common::{assert_reports_identical, assert_table2_identical, run_cfg};
use vfl::coordinator::metrics::AGGREGATOR;
use vfl::coordinator::{run_experiment, SecurityMode, TransportKind};
use vfl::net::evloop::swarm::{self, SwarmCfg};
use vfl::net::evloop::PollerKind;

/// An evloop training run is a sim training run, bit for bit — and
/// the aggregator really held every client concurrently on its loop.
#[test]
fn evloop_transport_bit_identical_to_sim_with_connection_peaks() {
    let sim = run_experiment(
        run_cfg("banking", SecurityMode::SecureExact, TransportKind::Sim),
        None,
    )
    .unwrap();
    let cfg = run_cfg("banking", SecurityMode::SecureExact, TransportKind::Evloop);
    let n_clients = cfg.model.n_clients();
    let ev = run_experiment(cfg, None).unwrap();
    assert_reports_identical(&sim, &ev, "evloop vs sim");
    assert_table2_identical(&sim.net, &ev.net);
    assert_eq!(
        ev.metrics.peak_connections(AGGREGATOR),
        n_clients as u64,
        "every client held live on the one event loop"
    );
    assert!(
        ev.metrics.peak_conn_buffered_bytes(AGGREGATOR) > 0,
        "per-connection queue depths were metered"
    );
    // the sim run has no sockets, so its connection peaks stay zero
    assert_eq!(sim.metrics.peak_connections(AGGREGATOR), 0);
}

/// The sharded event loop (`--evloop-threads K`) is the single loop,
/// bit for bit, at every K: same report, same Table-2 byte counters —
/// and the connection peak still counts the whole federation, because
/// the acceptor meters it while each loop only ever owns its ~n/K
/// share (their queue-depth peaks max-merge in).
#[test]
fn evloop_thread_sweep_bit_identical_to_sim() {
    let sim = run_experiment(
        run_cfg("banking", SecurityMode::SecureExact, TransportKind::Sim),
        None,
    )
    .unwrap();
    for k in [1usize, 2, 4] {
        let mut cfg = run_cfg("banking", SecurityMode::SecureExact, TransportKind::Evloop);
        cfg.evloop_threads = k;
        let n_clients = cfg.model.n_clients();
        let ev = run_experiment(cfg, None).unwrap();
        assert_reports_identical(&sim, &ev, &format!("evloop K={k} vs sim"));
        assert_table2_identical(&sim.net, &ev.net);
        assert_eq!(
            ev.metrics.peak_connections(AGGREGATOR),
            n_clients as u64,
            "K={k}: the acceptor meters the full federation, not one shard's share"
        );
        assert!(
            ev.metrics.peak_conn_buffered_bytes(AGGREGATOR) > 0,
            "K={k}: per-loop queue depths were max-merged into the report"
        );
    }
}

/// The sharded swarm server receives the identical byte stream: same
/// checksum and byte count as the single loop at every K, with the
/// connection peak still the full client count.
#[test]
fn swarm_server_thread_sweep_preserves_every_frame() {
    let single = swarm::run(&swarm_cfg(96)).unwrap();
    assert!(single.verified());
    for k in [2usize, 4] {
        let mut cfg = swarm_cfg(96);
        cfg.server_threads = k;
        let sharded = swarm::run(&cfg).unwrap();
        assert!(sharded.verified(), "K={k}: checksum mismatch");
        assert_eq!(sharded.checksum, single.checksum, "K={k}: payload fold differs");
        assert_eq!(sharded.bytes_received, single.bytes_received, "K={k}: bytes differ");
        assert_eq!(sharded.peak_live_connections, 96, "K={k}: connection peak");
    }
}

fn swarm_cfg(clients: usize) -> SwarmCfg {
    SwarmCfg {
        clients,
        rounds: 2,
        payload_words: 8,
        client_threads: 2,
        server_threads: 1,
        // pin the portable backend: CI proves poll(2) end to end while
        // the swarm CLI/bench default exercises epoll on Linux
        poller: PollerKind::PollFallback,
    }
}

/// Every payload frame a bounded swarm produces is received exactly
/// once — the checksum is a frame-accounting proof, not a smoke test.
#[test]
fn swarm_checksum_accounts_for_every_frame_on_poll_fallback() {
    let report = swarm::run(&swarm_cfg(96)).unwrap();
    assert!(
        report.verified(),
        "checksum {:#x} != expected {:#x}",
        report.checksum,
        report.expected_checksum
    );
    assert_eq!(report.peak_live_connections, 96);
    assert_eq!(report.poller, "poll");
    let frame_body = 6 + report.payload_words as u64 * 8;
    assert_eq!(report.bytes_received, 96 * 2 * frame_body);
}

/// The flat-memory claim, asserted with the transport's own meters:
/// 8× the clients, same per-connection buffering ceiling. A
/// thread-per-client design scales resident state with N; the event
/// loop's per-connection footprint is one partial frame + one bounded
/// queue, so the *peak single-connection* depth is a small constant.
#[test]
fn swarm_per_connection_memory_is_flat_in_client_count() {
    let small = swarm::run(&swarm_cfg(64)).unwrap();
    let big = swarm::run(&swarm_cfg(512)).unwrap();
    assert!(small.verified() && big.verified());
    assert_eq!(small.peak_live_connections, 64);
    assert_eq!(big.peak_live_connections, 512);
    // one payload frame on the wire is 4 (length) + 1 (kind) + body;
    // a connection never buffers more than a couple of frames of
    // in-flight bytes, however many neighbours the loop carries
    let frame_wire = 4 + 1 + (6 + 8 * 8) as u64;
    let ceiling = 4 * frame_wire;
    assert!(
        small.peak_conn_buffered_bytes <= ceiling,
        "64 clients: peak {} > ceiling {ceiling}",
        small.peak_conn_buffered_bytes
    );
    assert!(
        big.peak_conn_buffered_bytes <= ceiling,
        "512 clients: peak {} > ceiling {ceiling} — per-client memory grew with N",
        big.peak_conn_buffered_bytes
    );
}
