//! The streaming-pipeline tentpole invariants (`--chunk-words` /
//! `--shards`):
//!
//! * **Bit-identity.** A chunked run produces bit-identical
//!   predictions, parameters, losses, and accuracy to the monolithic
//!   path, on the simulator *and* the threaded transport — ℤ₂⁶⁴
//!   wrap-addition is order-independent, and every chunk's words equal
//!   the corresponding slice of the monolithic masked tensor.
//! * **Exact byte accounting.** Table-2 counters differ from the
//!   monolithic run by *exactly* the documented per-chunk header
//!   overhead (`streaming::chunk_overhead_bytes`): 22 bytes per chunk
//!   vs 11 per monolithic masked message, payload unchanged.
//! * **Memory.** The aggregator's peak fan-in buffer with chunking is
//!   strictly below the monolithic path's O(n·d) for banking's
//!   n = 5 ≥ 4 clients (asserted via the byte-metered peak counters).
//! * **Dropout.** Chunked dropout-tolerant runs keep the recovery
//!   semantics of `tests/dropout_recovery.rs`: crash runs are
//!   bit-identical to their zero-contribution twins — including a
//!   crash *mid-chunk-stream*, whose partial shard sums must be purged
//!   — and faults can target individual chunks.

mod common;

use common::{assert_reports_identical, assert_table2_identical, dropout_cfg, run_cfg};
use vfl::coordinator::metrics::AGGREGATOR;
use vfl::coordinator::parties::GradLayout;
use vfl::coordinator::streaming::chunk_overhead_bytes;
use vfl::coordinator::{run_experiment, RunConfig, RunReport, SecurityMode, TransportKind};
use vfl::net::{Addr, Fault, FaultPlan, Phase};

const CHUNK_WORDS: usize = 1000;
const SHARDS: usize = 4;

fn with_chunks(mut c: RunConfig) -> RunConfig {
    c.chunk_words = Some(CHUNK_WORDS);
    c.shards = SHARDS;
    c
}

fn secure_cfg(transport: TransportKind) -> RunConfig {
    run_cfg("banking", SecurityMode::SecureExact, transport)
}

/// The two masked-tensor lengths of a banking run: the (batch ×
/// hidden) activation and the full-length flat gradient.
fn tensor_lens(cfg: &RunConfig) -> (usize, usize) {
    (cfg.model.batch_size * cfg.model.hidden, GradLayout::new(&cfg.model).total)
}

/// Acceptance criterion: chunked ≡ monolithic bit-for-bit on sim and
/// threaded transports, with Table-2 counters matching exactly once
/// the documented per-chunk header overhead is accounted.
#[test]
fn chunked_run_bit_identical_to_monolithic_with_exact_byte_accounting() {
    let base = secure_cfg(TransportKind::Sim);
    let mono = run_experiment(base.clone(), None).unwrap();
    let (act_len, grad_len) = tensor_lens(&base);
    let per_act = chunk_overhead_bytes(act_len, SHARDS, CHUNK_WORDS);
    let per_grad = chunk_overhead_bytes(grad_len, SHARDS, CHUNK_WORDS);
    let rounds = base.train_rounds as u64;
    let n_passive = (base.model.n_clients() - 1) as u64;

    let mut runs: Vec<RunReport> = Vec::new();
    for transport in [TransportKind::Sim, TransportKind::Threaded] {
        let chunked = run_experiment(with_chunks(secure_cfg(transport)), None).unwrap();
        assert_reports_identical(&mono, &chunked, &format!("chunked vs monolithic {transport:?}"));

        let net = &chunked.net;
        let mnet = &mono.net;
        // setup traffic is untouched by chunking
        for i in 0..base.model.n_clients() {
            assert_eq!(
                net.sent_bytes(Addr::Client(i), Phase::Setup),
                mnet.sent_bytes(Addr::Client(i), Phase::Setup),
                "setup bytes client {i}"
            );
        }
        // active party: one chunked activation per train/test round
        assert_eq!(
            net.sent_bytes(Addr::Client(0), Phase::Training),
            mnet.sent_bytes(Addr::Client(0), Phase::Training) + rounds * per_act,
            "active training sent"
        );
        assert_eq!(
            net.sent_bytes(Addr::Client(0), Phase::Testing),
            mnet.sent_bytes(Addr::Client(0), Phase::Testing) + per_act,
            "active testing sent"
        );
        // passives: chunked activation + chunked gradient per train round
        for i in 1..base.model.n_clients() {
            assert_eq!(
                net.sent_bytes(Addr::Client(i), Phase::Training),
                mnet.sent_bytes(Addr::Client(i), Phase::Training)
                    + rounds * (per_act + per_grad),
                "passive {i} training sent"
            );
            assert_eq!(
                net.sent_bytes(Addr::Client(i), Phase::Testing),
                mnet.sent_bytes(Addr::Client(i), Phase::Testing) + per_act,
                "passive {i} testing sent"
            );
        }
        // the aggregator receives every chunk header once...
        assert_eq!(
            net.received_bytes(Addr::Aggregator, Phase::Training),
            mnet.received_bytes(Addr::Aggregator, Phase::Training)
                + rounds * ((n_passive + 1) * per_act + n_passive * per_grad),
            "aggregator training received"
        );
        assert_eq!(
            net.received_bytes(Addr::Aggregator, Phase::Testing),
            mnet.received_bytes(Addr::Aggregator, Phase::Testing) + (n_passive + 1) * per_act,
            "aggregator testing received"
        );
        // ...and sends exactly what the monolithic run sends (relays,
        // dz broadcasts, and the 1:1 gradient sum stay monolithic)
        for ph in [Phase::Setup, Phase::Training, Phase::Testing] {
            assert_eq!(
                net.sent_bytes(Addr::Aggregator, ph),
                mnet.sent_bytes(Addr::Aggregator, ph),
                "aggregator sent {ph:?}"
            );
        }
        runs.push(chunked);
    }
    // both chunked transports also agree with each other, counters included
    assert_reports_identical(&runs[0], &runs[1], "chunked sim vs chunked threaded");
    assert_table2_identical(&runs[0].net, &runs[1].net);
}

/// Acceptance criterion: with the base protocol (no dropout
/// tolerance), the chunked aggregator's peak fan-in buffer is strictly
/// below the monolithic path's O(n·d) for n = 5 ≥ 4 clients.
#[test]
fn chunked_aggregator_peak_memory_below_monolithic() {
    let base = secure_cfg(TransportKind::Sim);
    let (act_len, _) = tensor_lens(&base);
    let n = base.model.n_clients() as u64;
    let mono = run_experiment(base.clone(), None).unwrap();
    let chunked = run_experiment(with_chunks(base), None).unwrap();

    let mono_peak = mono.metrics.peak_buffered_bytes(AGGREGATOR);
    let chunked_peak = chunked.metrics.peak_buffered_bytes(AGGREGATOR);
    // the monolithic fan-in really holds one full vector per sender
    assert_eq!(mono_peak, n * (act_len as u64) * 8, "monolithic peak is n·d activation words");
    assert!(chunked_peak > 0, "chunked runs meter their buffers");
    assert!(
        chunked_peak < mono_peak,
        "streaming must buffer less than the monolithic fan-in: {chunked_peak} vs {mono_peak}"
    );
}

/// A chunked dropout-tolerant run recovers with unchanged semantics: a
/// client crashing after setup (before its first chunk) yields a run
/// bit-identical to the zero-contribution twin, to the same crash
/// under the monolithic path, and across transports.
#[test]
fn chunked_dropout_recovery_bit_identical_to_twin_and_monolithic() {
    // round 0 rotates: sends are keys(1), shares(2) — crash before any chunk
    let plan = FaultPlan::default().with(2, Fault::Crash { round: 0, after_sends: 2 });
    let cfg = |p: Option<FaultPlan>, t| with_chunks(dropout_cfg(3, p, t));
    let crash = run_experiment(cfg(Some(plan.clone()), TransportKind::Sim), None).unwrap();
    let twin = run_experiment(cfg(Some(plan.blank_twin()), TransportKind::Sim), None).unwrap();
    assert_reports_identical(&crash, &twin, "chunked crash vs chunked blank twin");
    // the same crash point under the monolithic path: identical reports
    let mono =
        run_experiment(dropout_cfg(3, Some(plan.clone()), TransportKind::Sim), None).unwrap();
    assert_reports_identical(&crash, &mono, "chunked crash vs monolithic crash");
    // and the threaded transport agrees bit-for-bit
    let thr = run_experiment(cfg(Some(plan), TransportKind::Threaded), None).unwrap();
    assert_reports_identical(&crash, &thr, "chunked crash sim vs threaded");
    assert_eq!(crash.losses.len(), 6);
    assert!(crash.losses.iter().all(|l| l.is_finite()));
}

/// A crash *mid-chunk-stream* leaves a partially assembled tensor at
/// the aggregator; the purge must discard the partial shard sums so
/// the recovery correction stays exact — still bit-identical to the
/// twin where the client contributes zeros.
#[test]
fn mid_stream_crash_purges_partial_shards() {
    // round 0 sends: keys(1), shares(2), then activation chunks — a
    // crash after 5 sends dies three chunks into the activation stream
    let plan = FaultPlan::default()
        .with(2, Fault::Crash { round: 0, after_sends: 2 })
        .with(3, Fault::Crash { round: 0, after_sends: 5 });
    let cfg = |p: Option<FaultPlan>, t| with_chunks(dropout_cfg(3, p, t));
    let crash = run_experiment(cfg(Some(plan.clone()), TransportKind::Sim), None).unwrap();
    let twin = run_experiment(cfg(Some(plan.blank_twin()), TransportKind::Sim), None).unwrap();
    assert_reports_identical(&crash, &twin, "mid-stream crash vs blank twin");
    let thr = run_experiment(cfg(Some(plan), TransportKind::Threaded), None).unwrap();
    assert_reports_identical(&crash, &thr, "mid-stream crash sim vs threaded");
}

/// Faults can now target individual chunks: losing one chunk of an
/// activation stream (sender alive) breaks the sender's stream, the
/// aggregator declares it dropped, and the round recovers — the same
/// on both transports.
#[test]
fn single_lost_chunk_declares_sender_dropped() {
    // round 1 does not rotate: sends are activation chunks from 0 —
    // drop the second chunk of client 3's stream
    let plan = FaultPlan::default().with(3, Fault::DropMsg { round: 1, nth: 1 });
    let cfg = |p: Option<FaultPlan>, t| with_chunks(dropout_cfg(3, p, t));
    let sim = run_experiment(cfg(Some(plan.clone()), TransportKind::Sim), None).unwrap();
    let thr = run_experiment(cfg(Some(plan), TransportKind::Threaded), None).unwrap();
    assert_reports_identical(&sim, &thr, "lost chunk sim vs threaded");
    assert!(sim.losses.iter().all(|l| l.is_finite()));
}

/// Sharding alone must not change results either: sweep a few
/// (chunk_words, shards) shapes — including chunk sizes that do not
/// divide the tensor length and the single-shard case — and require
/// bit-identity throughout.
#[test]
fn chunk_shape_sweep_is_bit_identical() {
    let mono = run_experiment(secure_cfg(TransportKind::Sim), None).unwrap();
    for (cw, shards) in [(16384, 1), (999, 1), (4096, 8), (333, 3)] {
        let mut c = secure_cfg(TransportKind::Sim);
        c.chunk_words = Some(cw);
        c.shards = shards;
        let run = run_experiment(c, None).unwrap();
        assert_reports_identical(&mono, &run, &format!("cw={cw} shards={shards}"));
    }
}
