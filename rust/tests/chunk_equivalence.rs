//! The streaming-pipeline tentpole invariants (`--chunk-words` /
//! `--shards` / `--agg-workers`):
//!
//! * **Bit-identity.** A chunked run produces bit-identical
//!   predictions, parameters, losses, and accuracy to the monolithic
//!   path — for *any* aggregator worker count — on the simulator, the
//!   threaded transport, and TCP. ℤ₂⁶⁴ wrap-addition is
//!   order-independent, every chunk's words equal the corresponding
//!   slice of the monolithic masked tensor, and the shard-parallel
//!   merge stitches disjoint ranges.
//! * **Exact byte accounting.** Table-2 counters differ from the
//!   monolithic run by *exactly* the documented per-chunk header
//!   overheads: 22 bytes per uplink `MaskedChunk` vs 11 per monolithic
//!   masked message (`streaming::chunk_overhead_bytes`), and 19 bytes
//!   per downlink `GradientChunk` vs the 9-byte `GradientSum` header
//!   (`streaming::grad_chunk_overhead_bytes`) — payload unchanged.
//! * **Memory.** The aggregator's chunked peak fan-in buffer is the
//!   O(d) shard accumulators — strictly below the monolithic O(n·d)
//!   for banking's n = 5 clients, now in the dropout-tolerant path
//!   too: purge history spills to the rollback log instead of holding
//!   per-sender shard sums in RAM.
//! * **Dropout.** Chunked dropout-tolerant runs keep the recovery
//!   semantics of `tests/dropout_recovery.rs`: crash runs are
//!   bit-identical to their zero-contribution twins — including a
//!   crash *mid-chunk-stream*, whose committed chunks the rollback log
//!   replays back out — and faults can target individual chunks.

mod common;

use common::{
    apply_env_axes, assert_reports_identical, assert_table2_identical, dropout_cfg, run_cfg,
    sessions, simd_isa,
};
use vfl::coordinator::metrics::AGGREGATOR;
use vfl::coordinator::parties::GradLayout;
use vfl::coordinator::streaming::{chunk_overhead_bytes, grad_chunk_overhead_bytes};
use vfl::coordinator::{
    build, run_experiment, summarize, RunConfig, RunReport, SecurityMode, TransportKind,
};
use vfl::net::{tcp, Addr, Fault, FaultPlan, Phase, StallClock};

const CHUNK_WORDS: usize = 1000;
const SHARDS: usize = 4;

fn with_chunks(mut c: RunConfig) -> RunConfig {
    c.chunk_words = Some(CHUNK_WORDS);
    c.shards = SHARDS;
    // re-apply after the reshape: the VFL_AGG_WORKERS axis is guarded
    // on a chunked config, which the fixture's first pass was not
    apply_env_axes(c)
}

fn secure_cfg(transport: TransportKind) -> RunConfig {
    run_cfg("banking", SecurityMode::SecureExact, transport)
}

/// The two masked-tensor lengths of a banking run: the (batch ×
/// hidden) activation and the full-length flat gradient.
fn tensor_lens(cfg: &RunConfig) -> (usize, usize) {
    (cfg.model.batch_size * cfg.model.hidden, GradLayout::new(&cfg.model).total)
}

/// Acceptance criterion: chunked ≡ monolithic bit-for-bit on sim and
/// threaded transports, with Table-2 counters matching exactly once
/// the documented per-chunk header overheads — uplink `MaskedChunk`s
/// *and* the chunked `GradientSum` downlink — are accounted.
#[test]
fn chunked_run_bit_identical_to_monolithic_with_exact_byte_accounting() {
    let base = secure_cfg(TransportKind::Sim);
    let mono = run_experiment(base.clone(), None).unwrap();
    let (act_len, grad_len) = tensor_lens(&base);
    let per_act = chunk_overhead_bytes(act_len, SHARDS, CHUNK_WORDS);
    let per_grad = chunk_overhead_bytes(grad_len, SHARDS, CHUNK_WORDS);
    let per_gsum = grad_chunk_overhead_bytes(grad_len, SHARDS, CHUNK_WORDS);
    let rounds = base.train_rounds as u64;
    let n_passive = (base.model.n_clients() - 1) as u64;

    let mut runs: Vec<RunReport> = Vec::new();
    for transport in [TransportKind::Sim, TransportKind::Threaded] {
        let chunked = run_experiment(with_chunks(secure_cfg(transport)), None).unwrap();
        assert_reports_identical(&mono, &chunked, &format!("chunked vs monolithic {transport:?}"));

        let net = &chunked.net;
        let mnet = &mono.net;
        // setup traffic is untouched by chunking
        for i in 0..base.model.n_clients() {
            assert_eq!(
                net.sent_bytes(Addr::Client(i), Phase::Setup),
                mnet.sent_bytes(Addr::Client(i), Phase::Setup),
                "setup bytes client {i}"
            );
        }
        // active party: one chunked activation per train/test round
        assert_eq!(
            net.sent_bytes(Addr::Client(0), Phase::Training),
            mnet.sent_bytes(Addr::Client(0), Phase::Training) + rounds * per_act,
            "active training sent"
        );
        assert_eq!(
            net.sent_bytes(Addr::Client(0), Phase::Testing),
            mnet.sent_bytes(Addr::Client(0), Phase::Testing) + per_act,
            "active testing sent"
        );
        // ...and receives the chunked gradient-sum downlink each round
        assert_eq!(
            net.received_bytes(Addr::Client(0), Phase::Training),
            mnet.received_bytes(Addr::Client(0), Phase::Training) + rounds * per_gsum,
            "active training received"
        );
        // passives: chunked activation + chunked gradient per train round
        for i in 1..base.model.n_clients() {
            assert_eq!(
                net.sent_bytes(Addr::Client(i), Phase::Training),
                mnet.sent_bytes(Addr::Client(i), Phase::Training)
                    + rounds * (per_act + per_grad),
                "passive {i} training sent"
            );
            assert_eq!(
                net.sent_bytes(Addr::Client(i), Phase::Testing),
                mnet.sent_bytes(Addr::Client(i), Phase::Testing) + per_act,
                "passive {i} testing sent"
            );
        }
        // the aggregator receives every uplink chunk header once...
        assert_eq!(
            net.received_bytes(Addr::Aggregator, Phase::Training),
            mnet.received_bytes(Addr::Aggregator, Phase::Training)
                + rounds * ((n_passive + 1) * per_act + n_passive * per_grad),
            "aggregator training received"
        );
        assert_eq!(
            net.received_bytes(Addr::Aggregator, Phase::Testing),
            mnet.received_bytes(Addr::Aggregator, Phase::Testing) + (n_passive + 1) * per_act,
            "aggregator testing received"
        );
        // ...and its sent side differs only by the chunked downlink
        // (relays, dz broadcasts, and setup stay monolithic)
        assert_eq!(
            net.sent_bytes(Addr::Aggregator, Phase::Training),
            mnet.sent_bytes(Addr::Aggregator, Phase::Training) + rounds * per_gsum,
            "aggregator sent Training"
        );
        for ph in [Phase::Setup, Phase::Testing] {
            assert_eq!(
                net.sent_bytes(Addr::Aggregator, ph),
                mnet.sent_bytes(Addr::Aggregator, ph),
                "aggregator sent {ph:?}"
            );
        }
        runs.push(chunked);
    }
    // both chunked transports also agree with each other, counters included
    assert_reports_identical(&runs[0], &runs[1], "chunked sim vs chunked threaded");
    assert_table2_identical(&runs[0].net, &runs[1].net);
}

/// Acceptance criterion: shard-parallel aggregation is invisible in
/// every report bit. Sweep worker counts — the inline path, one worker
/// per shard, and more workers than shards — against the monolithic
/// baseline and each other, on the simulator and the threaded
/// transport, counters included.
#[test]
fn agg_worker_sweep_bit_identical_across_transports() {
    let mono = run_experiment(secure_cfg(TransportKind::Sim), None).unwrap();
    let mut reference: Option<RunReport> = None;
    for workers in [1, SHARDS, SHARDS + 3] {
        for transport in [TransportKind::Sim, TransportKind::Threaded] {
            let mut c = with_chunks(secure_cfg(transport));
            c.agg_workers = workers;
            let run = run_experiment(c, None).unwrap();
            assert_reports_identical(
                &mono,
                &run,
                &format!("workers={workers} {transport:?} vs monolithic"),
            );
            match &reference {
                None => reference = Some(run),
                Some(r) => {
                    assert_reports_identical(r, &run, &format!("workers={workers} {transport:?}"));
                    assert_table2_identical(&r.net, &run.net);
                }
            }
        }
    }
}

/// Acceptance criterion (PR 8): pooled mask expansion is invisible in
/// every report bit. Sweep `--expand-workers` — the inline path, a
/// small pool, and more workers than windows are wide — against the
/// serial baseline and each other, monolithic *and* chunked, on the
/// simulator and the threaded transport. The window-partition property
/// (any partition of a tensor window wrap-adds to the monolithic mask)
/// is what makes the stitched sub-windows bit-identical; this proves
/// the wiring — client sessions and the aggregator's dropout
/// correction both route through the pool.
#[test]
fn expand_worker_sweep_bit_identical_across_transports() {
    let serial = run_experiment(secure_cfg(TransportKind::Sim), None).unwrap();
    let mut reference: Option<RunReport> = None;
    for workers in [1usize, 2, 5] {
        for chunked in [false, true] {
            for transport in [TransportKind::Sim, TransportKind::Threaded] {
                let mut c = secure_cfg(transport);
                if chunked {
                    c = with_chunks(c);
                }
                c.expand_workers = workers;
                let what = format!("expand_workers={workers} chunked={chunked} {transport:?}");
                let run = run_experiment(c, None).unwrap();
                assert_reports_identical(&serial, &run, &format!("{what} vs serial"));
                if !chunked {
                    // monolithic runs also keep Table-2 byte-identical to
                    // the serial baseline (chunked runs differ by the
                    // documented header overheads, proven elsewhere)
                    assert_table2_identical(&serial.net, &run.net);
                }
                match &reference {
                    None => reference = Some(run),
                    Some(r) => assert_reports_identical(r, &run, &what),
                }
            }
        }
    }
    // the dropout-recovery path routes the aggregator's total-mask
    // correction through the same pool — a crash run with a pooled
    // aggregator must match the serial crash run bit for bit
    let plan = FaultPlan::default().with(2, Fault::Crash { round: 0, after_sends: 2 });
    let serial_crash =
        run_experiment(dropout_cfg(3, Some(plan.clone()), TransportKind::Sim), None).unwrap();
    let mut c = dropout_cfg(3, Some(plan), TransportKind::Sim);
    c.expand_workers = 4;
    let pooled_crash = run_experiment(c, None).unwrap();
    assert_reports_identical(&serial_crash, &pooled_crash, "pooled dropout correction vs serial");
    assert_table2_identical(&serial_crash.net, &pooled_crash.net);
}

/// The TCP leg of the acceptance criterion: a real socket run with the
/// shard-parallel chunked pipeline produces the same losses and
/// predictions as the simulated run of the identical schedule.
#[test]
fn tcp_chunked_workers_match_sim() {
    let mut cfg = with_chunks(secure_cfg(TransportKind::Sim));
    cfg.agg_workers = 3;
    cfg.train_rounds = 2; // keep the socket run short
    let sim = run_experiment(cfg.clone(), None).unwrap();

    // bind port 0 first so there is no port race: clients connect to
    // the real port after the listener exists
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let n_clients = cfg.model.n_clients();

    let server_cfg = cfg.clone();
    let server = std::thread::spawn(move || {
        let built = build(&server_cfg, None).unwrap();
        let mut parties = built.parties;
        let aggregator = parties.remove(0);
        drop(parties);
        let clock = StallClock::from_config(server_cfg.stall_timeout_ms, server_cfg.stall_cap_ms);
        let out = tcp::serve_on(
            listener,
            aggregator,
            &built.schedule,
            n_clients,
            clock,
            server_cfg.rounds_in_flight,
        )?;
        Ok::<_, anyhow::Error>(summarize(&built.schedule, &built.test_labels, &out.notes))
    });

    let mut clients = Vec::new();
    for client in 0..n_clients {
        let cfg = cfg.clone();
        let addr = addr.clone();
        clients.push(std::thread::spawn(move || {
            let built = build(&cfg, None).unwrap();
            let mut parties = built.parties;
            let party = parties.remove(client + 1);
            drop(parties);
            tcp::join(&addr, client, party)
        }));
    }

    let summary = server.join().unwrap().unwrap();
    for c in clients {
        c.join().unwrap().unwrap();
    }
    assert_eq!(summary.losses, sim.losses, "TCP losses must match the simulated run");
    assert_eq!(summary.predictions, sim.predictions, "TCP predictions must match");
    assert_eq!(summary.test_accuracy, sim.test_accuracy);
}

/// Acceptance criterion: with the base protocol (no dropout
/// tolerance), the chunked aggregator's peak fan-in buffer is strictly
/// below the monolithic path's O(n·d) for n = 5 ≥ 4 clients — and the
/// base protocol never touches the rollback log.
#[test]
fn chunked_aggregator_peak_memory_below_monolithic() {
    let base = secure_cfg(TransportKind::Sim);
    let (act_len, _) = tensor_lens(&base);
    let n = base.model.n_clients() as u64;
    let mono = run_experiment(base.clone(), None).unwrap();
    let chunked = run_experiment(with_chunks(base), None).unwrap();

    let mono_peak = mono.metrics.peak_buffered_bytes(AGGREGATOR);
    let chunked_peak = chunked.metrics.peak_buffered_bytes(AGGREGATOR);
    // the monolithic fan-in really holds one full vector per sender
    assert_eq!(mono_peak, n * (act_len as u64) * 8, "monolithic peak is n·d activation words");
    assert!(chunked_peak > 0, "chunked runs meter their buffers");
    assert!(
        chunked_peak < mono_peak,
        "streaming must buffer less than the monolithic fan-in: {chunked_peak} vs {mono_peak}"
    );
    assert_eq!(chunked.metrics.peak_spilled_bytes(AGGREGATOR), 0, "base protocol never spills");
    // the per-shard peaks tile the full accumulator footprint
    let shard_sum: u64 =
        (0..SHARDS).map(|k| chunked.metrics.peak_shard_buffered_bytes(AGGREGATOR, k)).sum();
    assert!(shard_sum > 0, "per-shard peaks are metered");
    assert!(shard_sum <= chunked_peak, "shard accumulators are part of the resident peak");
}

/// Acceptance criterion (rollback log): a *dropout-tolerant* chunked
/// run — including one that actually drops a client mid-stream and
/// replays the log — keeps its aggregator RAM peak strictly below the
/// monolithic tolerant baseline, with the purge history spilled to the
/// rollback log instead.
#[test]
fn dropout_rollback_log_peak_below_monolithic() {
    let mono = run_experiment(dropout_cfg(3, None, TransportKind::Sim), None).unwrap();
    let mono_peak = mono.metrics.peak_buffered_bytes(AGGREGATOR);

    // a clean tolerant run and one that purges a mid-stream crasher
    let plan = FaultPlan::default().with(3, Fault::Crash { round: 0, after_sends: 5 });
    for (what, plan) in [("clean", None), ("mid-stream crash", Some(plan))] {
        let cfg = with_chunks(dropout_cfg(3, plan, TransportKind::Sim));
        let run = run_experiment(cfg, None).unwrap();
        let peak = run.metrics.peak_buffered_bytes(AGGREGATOR);
        assert!(
            peak < mono_peak,
            "{what}: tolerant chunked RAM peak must beat monolithic: {peak} vs {mono_peak}"
        );
        assert!(
            run.metrics.peak_spilled_bytes(AGGREGATOR) > 0,
            "{what}: tolerant chunked runs keep purge history in the rollback log"
        );
    }
}

/// A chunked dropout-tolerant run recovers with unchanged semantics: a
/// client crashing after setup (before its first chunk) yields a run
/// bit-identical to the zero-contribution twin, to the same crash
/// under the monolithic path, and across transports.
#[test]
fn chunked_dropout_recovery_bit_identical_to_twin_and_monolithic() {
    // round 0 rotates: sends are keys(1), shares(2) — crash before any chunk
    let plan = FaultPlan::default().with(2, Fault::Crash { round: 0, after_sends: 2 });
    let cfg = |p: Option<FaultPlan>, t| with_chunks(dropout_cfg(3, p, t));
    let crash = run_experiment(cfg(Some(plan.clone()), TransportKind::Sim), None).unwrap();
    let twin = run_experiment(cfg(Some(plan.blank_twin()), TransportKind::Sim), None).unwrap();
    assert_reports_identical(&crash, &twin, "chunked crash vs chunked blank twin");
    // the same crash point under the monolithic path: identical reports
    let mono =
        run_experiment(dropout_cfg(3, Some(plan.clone()), TransportKind::Sim), None).unwrap();
    assert_reports_identical(&crash, &mono, "chunked crash vs monolithic crash");
    // and the threaded transport agrees bit-for-bit
    let thr = run_experiment(cfg(Some(plan), TransportKind::Threaded), None).unwrap();
    assert_reports_identical(&crash, &thr, "chunked crash sim vs threaded");
    assert_eq!(crash.losses.len(), 6);
    assert!(crash.losses.iter().all(|l| l.is_finite()));
}

/// A crash *mid-chunk-stream* leaves already-committed chunks in the
/// shard accumulators; the purge must replay the rollback log and
/// subtract them so the recovery correction stays exact — still
/// bit-identical to the twin where the client contributes zeros.
#[test]
fn mid_stream_crash_purges_partial_shards() {
    // round 0 sends: keys(1), shares(2), then activation chunks — a
    // crash after 5 sends dies three chunks into the activation stream
    let plan = FaultPlan::default()
        .with(2, Fault::Crash { round: 0, after_sends: 2 })
        .with(3, Fault::Crash { round: 0, after_sends: 5 });
    let cfg = |p: Option<FaultPlan>, t| with_chunks(dropout_cfg(3, p, t));
    let crash = run_experiment(cfg(Some(plan.clone()), TransportKind::Sim), None).unwrap();
    let twin = run_experiment(cfg(Some(plan.blank_twin()), TransportKind::Sim), None).unwrap();
    assert_reports_identical(&crash, &twin, "mid-stream crash vs blank twin");
    let thr = run_experiment(cfg(Some(plan), TransportKind::Threaded), None).unwrap();
    assert_reports_identical(&crash, &thr, "mid-stream crash sim vs threaded");
}

/// Faults can now target individual chunks: losing one chunk of an
/// activation stream (sender alive) breaks the sender's stream, the
/// aggregator rolls its committed chunks back, declares it dropped,
/// and the round recovers — the same on both transports.
#[test]
fn single_lost_chunk_declares_sender_dropped() {
    // round 1 does not rotate: sends are activation chunks from 0 —
    // drop the second chunk of client 3's stream
    let plan = FaultPlan::default().with(3, Fault::DropMsg { round: 1, nth: 1 });
    let cfg = |p: Option<FaultPlan>, t| with_chunks(dropout_cfg(3, p, t));
    let sim = run_experiment(cfg(Some(plan.clone()), TransportKind::Sim), None).unwrap();
    let thr = run_experiment(cfg(Some(plan), TransportKind::Threaded), None).unwrap();
    assert_reports_identical(&sim, &thr, "lost chunk sim vs threaded");
    assert!(sim.losses.iter().all(|l| l.is_finite()));
}

/// The SIMD leg of the gate: mask expansion through the runtime
/// dispatch (4-block ChaCha20 core + lane-chunked ℤ₂⁶⁴ folds) is
/// bit-identical to the scalar reference for every chunk shape the
/// streaming pipeline can produce — ragged offsets, windows straddling
/// block boundaries, and partitions that must reassemble the
/// monolithic mask exactly. Under the `VFL_SIMD=off` CI axis both legs
/// run scalar and the test degenerates to scalar ≡ scalar, which is
/// why the log line names the active ISA.
#[test]
fn simd_mask_expansion_bit_identical_to_scalar_across_chunk_shapes() {
    eprintln!("simd sweep: dispatch isa = {}", simd_isa());
    let sess = sessions(5, 0xC0DE);
    let me = &sess[2];
    let stream = me.total_mask_stream(7, 1);
    // windows at awkward offsets/lengths: partial leading block, exact
    // 4-block groups, straddles, and a long ragged span
    for (offset, len) in
        [(0usize, 1usize), (0, 8), (0, 32), (3, 5), (5, 32), (7, 97), (31, 33), (256, 513), (1000, 2048)]
    {
        let mut simd = vec![0u64; len];
        stream.add_window(offset, &mut simd);
        let mut scalar = vec![0u64; len];
        stream.add_window_scalar(offset, &mut scalar);
        assert_eq!(simd, scalar, "window ({offset}, {len})");
    }
    // any chunk partition must reassemble the monolithic total mask
    let total = me.total_mask(7, 1, 5000);
    for cw in [1usize, 7, 32, 999, 5000] {
        let mut stitched = vec![0u64; 5000];
        for start in (0..5000).step_by(cw) {
            let end = (start + cw).min(5000);
            stream.add_window(start, &mut stitched[start..end]);
        }
        assert_eq!(stitched, total, "partition cw={cw}");
    }
}

/// Sharding alone must not change results either: sweep a few
/// (chunk_words, shards, workers) shapes — including chunk sizes that
/// do not divide the tensor length and the single-shard case — and
/// require bit-identity throughout.
#[test]
fn chunk_shape_sweep_is_bit_identical() {
    let mono = run_experiment(secure_cfg(TransportKind::Sim), None).unwrap();
    for (cw, shards, workers) in [(16384, 1, 1), (999, 1, 1), (4096, 8, 3), (333, 3, 2)] {
        let mut c = secure_cfg(TransportKind::Sim);
        c.chunk_words = Some(cw);
        c.shards = shards;
        c.agg_workers = workers;
        let run = run_experiment(c, None).unwrap();
        assert_reports_identical(&mono, &run, &format!("cw={cw} shards={shards} w={workers}"));
    }
}
