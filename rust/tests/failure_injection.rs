//! Failure-injection tests: tampered ciphertexts, wrong-epoch masks,
//! malformed messages, dropped shares — the protocol must fail *safe*
//! (reject / stay masked), never silently mis-train.

mod common;

use common::sessions;
use vfl::coordinator::parties::{open_id, seal_id};
use vfl::crypto::rng::DetRng;
use vfl::crypto::shamir;
use vfl::secagg::{aggregate, FixedPoint};

/// A tampered sealed sample-ID must be rejected (AEAD), which the
/// protocol treats as "not my sample" — privacy-preserving degradation.
#[test]
fn tampered_batch_entry_rejected() {
    let key = [5u8; 32];
    let sealed = seal_id(&key, 1, 0, 42);
    for byte in 0..sealed.len() {
        let mut bad = sealed.clone();
        bad[byte] ^= 0x01;
        assert_eq!(open_id(&key, 1, 0, &bad), None, "flip at {byte} must fail auth");
    }
    // replay under a different (round, seq) also fails (nonce binding)
    assert_eq!(open_id(&key, 2, 0, &sealed), None);
    assert_eq!(open_id(&key, 1, 1, &sealed), None);
}

/// An attacker substituting a stale masked vector (from an earlier
/// round) corrupts the aggregate — but only into noise, never into a
/// plausible wrong value near the true sum.
#[test]
fn stale_round_vector_stays_masked() {
    let sessions = sessions(3, 1);
    let t = vec![1.0f32; 16];
    let fresh: Vec<Vec<u64>> = sessions.iter().map(|s| s.mask_tensor(&t, 5, 0)).collect();
    let stale = sessions[2].mask_tensor(&t, 4, 0); // wrong round
    let mixed = vec![fresh[0].clone(), fresh[1].clone(), stale];
    let out = aggregate(&FixedPoint::default(), &mixed);
    let want = 3.0f32;
    // masks don't cancel → values are uniform garbage, far from `want`
    let near = out.iter().filter(|v| (**v - want).abs() < 1.0).count();
    assert!(near <= 1, "stale vector must not produce a near-correct sum");
}

/// Missing one client's vector leaves the sum masked (the dropout case
/// before recovery) — for every client.
#[test]
fn any_single_missing_client_masks_the_sum() {
    let n = 4;
    let sessions = sessions(n, 2);
    let t = vec![2.5f32; 8];
    let masked: Vec<Vec<u64>> = sessions.iter().map(|s| s.mask_tensor(&t, 0, 0)).collect();
    let want_partial = 2.5 * (n as f32 - 1.0);
    for skip in 0..n {
        let subset: Vec<Vec<u64>> = masked
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != skip)
            .map(|(_, m)| m.clone())
            .collect();
        let out = aggregate(&FixedPoint::default(), &subset);
        let near = out.iter().filter(|v| (**v - want_partial).abs() < 1.0).count();
        assert!(near <= 1, "skipping client {skip} must keep the sum masked");
    }
}

/// Shamir reconstruction with a corrupted share yields a wrong secret
/// (detectable via the seed commitment), not a crash.
#[test]
fn corrupted_share_detected_by_commitment() {
    use vfl::secagg::dropout::seed_commitment;
    let mut rng = DetRng::from_seed(3).as_fill_fn();
    let seed = [7u8; 32];
    let shares = shamir::split_bytes(&seed, 3, 5, &mut rng);
    // clean reconstruction matches the commitment
    let clean = shamir::reconstruct_bytes(&shares[..3], 32);
    assert_eq!(
        seed_commitment(&clean.clone().try_into().unwrap()),
        seed_commitment(&seed)
    );
    // corrupt one share value
    let mut bad = shares[..3].to_vec();
    bad[1][0].y ^= 1;
    let wrong = shamir::reconstruct_bytes(&bad, 32);
    assert_ne!(wrong, seed.to_vec());
    let wrong_arr: [u8; 32] = wrong.try_into().unwrap();
    assert_ne!(seed_commitment(&wrong_arr), seed_commitment(&seed));
}

/// Mismatched tensor lengths must panic loudly at the aggregator
/// (shape confusion is a protocol violation, not a recoverable state).
#[test]
#[should_panic]
fn length_mismatch_panics() {
    let sessions = sessions(2, 4);
    let a = sessions[0].mask_tensor(&vec![1.0; 8], 0, 0);
    let b = sessions[1].mask_tensor(&vec![1.0; 9], 0, 0);
    let _ = aggregate(&FixedPoint::default(), &[a, b]);
}

/// Decoding a truncated KeyDirectory must error, not panic.
#[test]
fn truncated_directory_errors() {
    use vfl::coordinator::messages::{Msg, WireKeys};
    let dir = Msg::KeyDirectory {
        epoch: 1,
        all: vec![WireKeys { from: 0, keys: vec![Some([1u8; 32]), None] }],
    };
    let enc = dir.encode();
    for cut in 0..enc.len() {
        assert!(Msg::decode(&enc[..cut]).is_err(), "cut={cut}");
    }
}
