//! The windowed round scheduler's tentpole invariants
//! (`--rounds-in-flight`):
//!
//! * **Bit-identity across window widths.** W ∈ {1, 2, 4} produce
//!   bit-identical predictions, parameters, losses, accuracy, *and*
//!   per-(phase, node, direction) Table-2 byte counters, on the
//!   simulator, the threaded transport, TCP, and the socket event
//!   loop — for the monolithic path and the chunked shard-parallel
//!   streaming pipeline alike.
//!   Rounds start in schedule order; setup/rotation rounds and phase
//!   boundaries are barriers; training rounds chain through the active
//!   party's SGD data dependency — so a wider window can only shrink
//!   idle gaps, never change a value.
//! * **W = 1 is the serial driver.** The width-1 run is the
//!   pre-refactor behavior bit-for-bit (it *is* the baseline every
//!   other width is compared against).
//! * **Dropout drains the window.** A crash mid-window declares the
//!   client dropped, the aggregator's `WindowDrain` note pins the
//!   scheduler to one round in flight, and the recovered run stays
//!   bit-identical to its zero-contribution blank twin and to the
//!   serial (W = 1) crash run.
//! * **Overlap is real and measured.** With W > 1 the pipeline
//!   counters report overlapped round starts (testing rounds are
//!   mutually independent), and with W = 1 they report none.

mod common;

use common::{
    assert_reports_identical, assert_table2_identical, dropout_cfg, run_cfg,
};
use vfl::coordinator::{
    build, run_experiment, summarize, RunConfig, RunReport, SecurityMode, TransportKind,
};
use vfl::net::{tcp, Fault, FaultPlan, StallClock};

const WIDTHS: [usize; 3] = [1, 2, 4];

/// Fixture config with the window pinned back to serial: this suite
/// sweeps widths itself, so the `VFL_ROUNDS_IN_FLIGHT` CI axis (which
/// `run_cfg` applies) must not skew its W = 1 baselines.
fn secure_cfg(transport: TransportKind) -> RunConfig {
    let mut c = run_cfg("banking", SecurityMode::SecureExact, transport);
    c.rounds_in_flight = 1;
    c
}

fn with_chunks(mut c: RunConfig) -> RunConfig {
    c.chunk_words = Some(1000);
    c.shards = 4;
    c.agg_workers = 3;
    c
}

/// Acceptance criterion: the window sweep is invisible in every report
/// bit and every Table-2 counter, monolithic and chunked, on the
/// simulator, the threaded transport, and (on unix) the socket event
/// loop. More test rounds than the default so the windowed testing
/// phase genuinely overlaps.
#[test]
fn window_sweep_bit_identical_across_transports() {
    for chunked in [false, true] {
        let mk = |transport| {
            let mut c = secure_cfg(transport);
            // three full testing batches need ≥ 3·256 test rows (the
            // 20% split of 4096), so the testing window really fills
            c.n_rows = 4096;
            c.test_rounds = 3;
            if chunked {
                c = with_chunks(c);
            }
            c
        };
        let mut baseline: Option<RunReport> = None;
        #[cfg(unix)]
        let transports = [TransportKind::Sim, TransportKind::Threaded, TransportKind::Evloop];
        #[cfg(not(unix))]
        let transports = [TransportKind::Sim, TransportKind::Threaded];
        for transport in transports {
            for width in WIDTHS {
                let mut c = mk(transport);
                c.rounds_in_flight = width;
                let run = run_experiment(c, None).unwrap();
                match &baseline {
                    None => baseline = Some(run), // sim, W = 1: the serial driver
                    Some(b) => {
                        let what = format!("chunked={chunked} {transport:?} W={width}");
                        assert_reports_identical(b, &run, &what);
                        assert_table2_identical(&b.net, &run.net);
                    }
                }
            }
        }
    }
}

/// The plain and float-masked modes ride the same scheduler: per-round
/// contexts isolate their float fan-ins, and the aggregator still sums
/// in client order, so the sweep is bit-identical there too.
#[test]
fn window_sweep_bit_identical_in_other_security_modes() {
    for mode in [SecurityMode::Plain, SecurityMode::SecureFloat] {
        let mut baseline: Option<RunReport> = None;
        for width in WIDTHS {
            let mut c = run_cfg("banking", mode, TransportKind::Sim);
            c.n_rows = 4096; // fit three full testing batches
            c.test_rounds = 3;
            c.rounds_in_flight = width; // overrides the CI env axis
            let run = run_experiment(c, None).unwrap();
            match &baseline {
                None => baseline = Some(run),
                Some(b) => {
                    assert_reports_identical(b, &run, &format!("{mode:?} W={width}"));
                    assert_table2_identical(&b.net, &run.net);
                }
            }
        }
    }
}

/// The TCP leg: a real socket run at every window width produces the
/// same losses and predictions as the serial simulated run.
#[test]
fn tcp_window_sweep_matches_sim() {
    let mut cfg = secure_cfg(TransportKind::Sim);
    cfg.train_rounds = 2; // keep the socket runs short
    cfg.n_rows = 4096; // fit two full testing batches
    cfg.test_rounds = 2;
    let sim = run_experiment(cfg.clone(), None).unwrap();

    for width in WIDTHS {
        let mut cfg = cfg.clone();
        cfg.rounds_in_flight = width;
        // bind port 0 first so there is no port race: clients connect
        // to the real port after the listener exists
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let n_clients = cfg.model.n_clients();

        let server_cfg = cfg.clone();
        let server = std::thread::spawn(move || {
            let built = build(&server_cfg, None).unwrap();
            let mut parties = built.parties;
            let aggregator = parties.remove(0);
            drop(parties);
            let clock =
                StallClock::from_config(server_cfg.stall_timeout_ms, server_cfg.stall_cap_ms);
            let out = tcp::serve_on(
                listener,
                aggregator,
                &built.schedule,
                n_clients,
                clock,
                server_cfg.rounds_in_flight,
            )?;
            Ok::<_, anyhow::Error>((
                summarize(&built.schedule, &built.test_labels, &out.notes),
                out,
            ))
        });

        let mut clients = Vec::new();
        for client in 0..n_clients {
            let cfg = cfg.clone();
            let addr = addr.clone();
            clients.push(std::thread::spawn(move || {
                let built = build(&cfg, None).unwrap();
                let mut parties = built.parties;
                let party = parties.remove(client + 1);
                drop(parties);
                tcp::join(&addr, client, party)
            }));
        }

        let (summary, out) = server.join().unwrap().unwrap();
        for c in clients {
            c.join().unwrap().unwrap();
        }
        assert_eq!(summary.losses, sim.losses, "W={width}: TCP losses must match sim");
        assert_eq!(summary.predictions, sim.predictions, "W={width}: TCP predictions");
        assert_eq!(summary.test_accuracy, sim.test_accuracy, "W={width}");
        if width > 1 {
            assert!(
                out.metrics.pipeline().max_in_flight >= 1,
                "W={width}: the serve loop records pipeline stats"
            );
        }
    }
}

/// Acceptance criterion: a dropout mid-window drains the scheduler to
/// one round in flight and recovery semantics are unchanged — the
/// crash run at W ∈ {2, 4} is bit-identical to its zero-contribution
/// blank twin and to the serial crash run.
#[test]
fn dropout_mid_window_drains_and_matches_twin() {
    // client 3 is blanked (zero feature rows — the algebraic-twin
    // device: its pre-crash rounds contribute masked zeros, so the
    // whole run can be compared bit-for-bit against the twin where it
    // stays alive) and crashes mid-round-2, after its activation but
    // before its gradient, in the middle of the training phase the
    // window pipelines
    let plan =
        FaultPlan::blank(&[3]).with(3, Fault::Crash { round: 2, after_sends: 1 });
    let mut serial_cfg = dropout_cfg(3, Some(plan.clone()), TransportKind::Sim);
    serial_cfg.rounds_in_flight = 1; // the serial baseline, env axis or not
    let serial = run_experiment(serial_cfg, None).unwrap();
    for width in [2usize, 4] {
        let mk = |p: Option<FaultPlan>| {
            let mut c = dropout_cfg(3, p, TransportKind::Sim);
            c.rounds_in_flight = width;
            c
        };
        let crash = run_experiment(mk(Some(plan.clone())), None).unwrap();
        let twin = run_experiment(mk(Some(plan.blank_twin())), None).unwrap();
        assert_reports_identical(&crash, &twin, &format!("W={width} crash vs blank twin"));
        assert_reports_identical(&crash, &serial, &format!("W={width} crash vs serial crash"));
        // the threaded transport agrees bit-for-bit
        let mut c = dropout_cfg(3, Some(plan.clone()), TransportKind::Threaded);
        c.rounds_in_flight = width;
        let thr = run_experiment(c, None).unwrap();
        assert_reports_identical(&crash, &thr, &format!("W={width} crash sim vs threaded"));
    }
}

/// The pipeline counters: a serial run reports zero overlap; a W = 4
/// run with several independent testing rounds reports overlapped
/// starts and a deeper in-flight peak.
#[test]
fn pipeline_counters_measure_the_overlap() {
    let mut serial = secure_cfg(TransportKind::Sim);
    serial.n_rows = 4096; // fit three full testing batches
    serial.test_rounds = 3;
    let serial = run_experiment(serial, None).unwrap();
    let p1 = serial.metrics.pipeline();
    assert!(p1.rounds_started >= 10, "setup + 6 train + 3 test: {}", p1.rounds_started);
    assert_eq!(p1.overlapped_starts, 0, "serial runs never overlap");
    assert_eq!(p1.max_in_flight, 1);

    let mut wide = secure_cfg(TransportKind::Sim);
    wide.n_rows = 4096;
    wide.test_rounds = 3;
    wide.rounds_in_flight = 4;
    let wide = run_experiment(wide, None).unwrap();
    let p4 = wide.metrics.pipeline();
    assert_eq!(p4.rounds_started, p1.rounds_started, "same schedule");
    assert!(
        p4.overlapped_starts >= 2,
        "3 independent test rounds must pipeline: {}",
        p4.overlapped_starts
    );
    assert!(p4.max_in_flight >= 3, "testing window fills: {}", p4.max_in_flight);
    // and the overlap changed no output bit
    assert_reports_identical(&serial, &wide, "serial vs W=4");
}
