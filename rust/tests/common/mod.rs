//! Shared integration-test fixtures (the `mod common;` pattern):
//! every suite in `tests/` declares `mod common;` and builds its
//! experiment configs, SA sessions, and report assertions from here
//! instead of repeating them per file.
#![allow(dead_code)]

use std::path::PathBuf;

use vfl::coordinator::messages::Msg;
use vfl::coordinator::{BackendKind, RunConfig, RunReport, SecurityMode, TransportKind};
use vfl::crypto::rng::DetRng;
use vfl::net::{Addr, FaultPlan, Network, Phase};
use vfl::secagg::{setup_all, ClientSession};

/// The standard small experiment: reference backend, 6 training rounds
/// (crossing one K = 5 key-rotation boundary), one test round. Applies
/// the `VFL_ROUNDS_IN_FLIGHT`, `VFL_TRANSPORT`, `VFL_EXPAND_WORKERS`,
/// and `VFL_EVLOOP_THREADS` CI axes (see [`apply_env_window`] /
/// [`apply_env_transport`] / [`apply_env_expand_workers`] /
/// [`apply_env_evloop_threads`]).
pub fn run_cfg(dataset: &str, mode: SecurityMode, transport: TransportKind) -> RunConfig {
    let mut c = RunConfig::test(dataset).unwrap();
    c.security = mode;
    c.backend = BackendKind::Reference;
    c.transport = transport;
    c.train_rounds = 6;
    c.test_rounds = 1;
    apply_env_evloop_threads(apply_env_expand_workers(apply_env_transport(apply_env_window(c))))
}

/// CI window-matrix hook: when `VFL_ROUNDS_IN_FLIGHT` is set, every
/// fixture-built run uses that round-window width, so the pipelined
/// scheduler is exercised by the same equivalence suites that prove
/// the serial one (bit-identity makes the override invisible to every
/// assertion — including the dropout suites, whose crash runs and
/// blank twins both drain the window identically).
pub fn apply_env_window(mut c: RunConfig) -> RunConfig {
    if let Ok(w) = std::env::var("VFL_ROUNDS_IN_FLIGHT") {
        // a set-but-unparseable value must fail the suite, not
        // silently run the serial path CI thinks it is NOT running
        c.rounds_in_flight = w
            .trim()
            .parse()
            .unwrap_or_else(|e| panic!("bad VFL_ROUNDS_IN_FLIGHT {w:?}: {e}"));
    }
    c
}

/// CI transport-matrix hook: when `VFL_TRANSPORT` is set, every
/// fixture-built run uses that transport (`sim` | `threaded` |
/// `evloop`), so the equivalence suites that prove the simulator also
/// exercise the socket event loop end to end (bit-identity makes the
/// override invisible to every assertion).
pub fn apply_env_transport(mut c: RunConfig) -> RunConfig {
    if let Ok(t) = std::env::var("VFL_TRANSPORT") {
        // a set-but-unrecognized value must fail the suite, not
        // silently run a transport CI thinks it is NOT running
        c.transport = match t.trim() {
            "sim" => TransportKind::Sim,
            "threaded" => TransportKind::Threaded,
            "evloop" => TransportKind::Evloop,
            other => panic!("bad VFL_TRANSPORT {other:?} (want sim|threaded|evloop)"),
        };
    }
    c
}

/// CI worker-matrix hook: when `VFL_AGG_WORKERS` is set, chunked
/// configs run their aggregator fan-ins with that many shard workers,
/// so the parallel path is exercised by the same equivalence suites
/// that prove the sequential one (bit-identity makes the override
/// invisible to every assertion). Monolithic configs are unaffected —
/// worker counts only apply to the chunked pipeline.
pub fn apply_env_workers(mut c: RunConfig) -> RunConfig {
    if c.chunk_words.is_some() {
        if let Ok(w) = std::env::var("VFL_AGG_WORKERS") {
            // a set-but-unparseable value must fail the suite, not
            // silently fall back to the inline path CI thinks it is
            // NOT running
            c.agg_workers = w
                .trim()
                .parse()
                .unwrap_or_else(|e| panic!("bad VFL_AGG_WORKERS {w:?}: {e}"));
        }
    }
    c
}

/// CI expand-pool hook: when `VFL_EXPAND_WORKERS` is set, every
/// fixture-built run expands its masks on that many pool workers, so
/// the parallel expansion path is exercised by the same equivalence
/// suites that prove the serial one (bit-identity makes the override
/// invisible to every assertion). Unlike `VFL_AGG_WORKERS`, this
/// applies to monolithic and chunked configs alike — mask expansion
/// exists on both paths.
pub fn apply_env_expand_workers(mut c: RunConfig) -> RunConfig {
    if let Ok(w) = std::env::var("VFL_EXPAND_WORKERS") {
        // a set-but-unparseable value must fail the suite, not
        // silently run the serial path CI thinks it is NOT running
        c.expand_workers = w
            .trim()
            .parse()
            .unwrap_or_else(|e| panic!("bad VFL_EXPAND_WORKERS {w:?}: {e}"));
    }
    c
}

/// CI evloop-shard hook: when `VFL_EVLOOP_THREADS` is set, every
/// fixture-built run that ends up on the evloop transport shards its
/// connections across that many poller threads. Inert on sim/threaded
/// runs — the knob only reaches `EvloopTransport` — so it composes
/// with `VFL_TRANSPORT=evloop` to turn the whole equivalence matrix
/// into a sharded-loop proof.
pub fn apply_env_evloop_threads(mut c: RunConfig) -> RunConfig {
    if let Ok(k) = std::env::var("VFL_EVLOOP_THREADS") {
        // a set-but-unparseable value must fail the suite, not
        // silently run the single loop CI thinks it is NOT running
        c.evloop_threads = k
            .trim()
            .parse()
            .unwrap_or_else(|e| panic!("bad VFL_EVLOOP_THREADS {k:?}: {e}"));
    }
    c
}

/// The SIMD ISA this test process dispatches to — "scalar" under the
/// `VFL_SIMD=off` CI axis, "avx2"/"neon" where the hardware has them.
/// Suites that assert SIMD ≡ scalar log it so a CI leg that silently
/// probed scalar (and therefore proved nothing new) is visible.
pub fn simd_isa() -> &'static str {
    vfl::crypto::simd::active_isa().name()
}

/// A dropout-tolerant banking run (5 clients: 1 active + 4 passive):
/// SecureExact, Shamir threshold `t`, optional fault plan.
pub fn dropout_cfg(t: usize, plan: Option<FaultPlan>, transport: TransportKind) -> RunConfig {
    let mut c = run_cfg("banking", SecurityMode::SecureExact, transport);
    c.shamir_threshold = Some(t);
    c.fault_plan = plan;
    // shrink the threaded dropout-detection window: rounds take
    // milliseconds here, and each declared dropout otherwise sleeps
    // through full 500 ms quiescence windows
    c.stall_timeout_ms = Some(100);
    c
}

/// `n` fully set-up SA client sessions with deterministic keys.
pub fn sessions(n: usize, seed: u64) -> Vec<ClientSession> {
    let mut rng = DetRng::from_seed(seed);
    setup_all(n, 0, &mut rng)
}

/// encode ∘ decode = id for one protocol message.
pub fn assert_msg_roundtrip(m: &Msg) {
    let enc = m.encode();
    assert_eq!(&Msg::decode(&enc).unwrap(), m, "roundtrip failed for {m:?}");
}

/// Table-2 byte counters identical across two runs, per (phase, node,
/// direction).
pub fn assert_table2_identical(a: &Network, b: &Network) {
    assert_eq!(a.n_clients(), b.n_clients());
    assert_eq!(a.messages, b.messages, "message counts differ");
    let phases = [Phase::Setup, Phase::Training, Phase::Testing];
    let mut nodes = vec![Addr::Aggregator];
    nodes.extend((0..a.n_clients()).map(Addr::Client));
    for ph in phases {
        for &n in &nodes {
            assert_eq!(
                a.sent_bytes(n, ph),
                b.sent_bytes(n, ph),
                "sent bytes differ at {n:?}/{ph:?}"
            );
            assert_eq!(
                a.received_bytes(n, ph),
                b.received_bytes(n, ph),
                "received bytes differ at {n:?}/{ph:?}"
            );
        }
    }
}

/// Bit-identity of everything a run reports: losses, predictions,
/// labels, accuracy, final parameters, setup count.
pub fn assert_reports_identical(a: &RunReport, b: &RunReport, what: &str) {
    assert_eq!(a.losses, b.losses, "{what}: losses must be bit-identical");
    assert_eq!(a.predictions, b.predictions, "{what}: predictions must be bit-identical");
    assert_eq!(a.prediction_labels, b.prediction_labels, "{what}: labels differ");
    assert_eq!(a.test_accuracy, b.test_accuracy, "{what}: accuracy differs");
    assert_eq!(
        a.final_params.flatten(),
        b.final_params.flatten(),
        "{what}: final parameters must be bit-identical"
    );
    assert_eq!(a.setups, b.setups, "{what}: setup counts differ");
}

/// Where `make artifacts` puts the AOT HLO programs.
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Whether the PJRT feature + artifacts are available (PJRT suites
/// skip with a clear message otherwise).
pub fn have_artifacts() -> bool {
    if !vfl::runtime::pjrt_enabled() {
        eprintln!("skipping: built without the `pjrt` feature");
        return false;
    }
    if !artifacts_dir().join("banking_global_step.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return false;
    }
    true
}
