//! Shared integration-test fixtures (the `mod common;` pattern):
//! every suite in `tests/` declares `mod common;` and builds its
//! experiment configs, SA sessions, and report assertions from here
//! instead of repeating them per file.
#![allow(dead_code)]

use std::path::PathBuf;

use vfl::coordinator::messages::Msg;
use vfl::coordinator::{BackendKind, RunConfig, RunReport, SecurityMode, TransportKind};
use vfl::crypto::rng::DetRng;
use vfl::net::{Addr, FaultPlan, Network, Phase};
use vfl::secagg::{setup_all, ClientSession};

/// The standard small experiment: reference backend, 6 training rounds
/// (crossing one K = 5 key-rotation boundary), one test round. Applies
/// every CI environment axis (see [`apply_env_axes`]).
pub fn run_cfg(dataset: &str, mode: SecurityMode, transport: TransportKind) -> RunConfig {
    let mut c = RunConfig::test(dataset).unwrap();
    c.security = mode;
    c.backend = BackendKind::Reference;
    c.transport = transport;
    c.train_rounds = 6;
    c.test_rounds = 1;
    apply_env_axes(c)
}

/// Parse helper shared by the numeric axes: a set-but-invalid value
/// must fail the suite, not silently run the default path CI thinks
/// it is NOT running.
fn axis_usize(name: &str, v: &str) -> usize {
    v.trim().parse().unwrap_or_else(|e| panic!("bad {name} {v:?}: {e}"))
}

/// The CI environment axes, as one table: variable name → how a set
/// value lands in the config. Adding an axis means adding a row here
/// and registering the variable in `tools/vflint/env_registry.txt`
/// (the lint cross-checks the registry against `ci.yml`).
///
/// Guarded rows are inert where the knob cannot apply — and because
/// config shape can change *after* the fixture runs (a suite that
/// turns on chunking, say), [`apply_env_axes`] is idempotent and safe
/// to re-apply to a reshaped config.
const ENV_AXES: &[(&str, fn(&mut RunConfig, &str))] = &[
    // pipelined round window: every fixture-built run uses this width;
    // bit-identity makes the override invisible to every assertion,
    // including the dropout suites (crash runs and blank twins drain
    // the window identically)
    ("VFL_ROUNDS_IN_FLIGHT", |c, v| {
        c.rounds_in_flight = axis_usize("VFL_ROUNDS_IN_FLIGHT", v);
    }),
    // transport matrix: the equivalence suites that prove the
    // simulator also exercise the threaded channels and the socket
    // event loop end to end
    ("VFL_TRANSPORT", |c, v| {
        c.transport = match v.trim() {
            "sim" => TransportKind::Sim,
            "threaded" => TransportKind::Threaded,
            "evloop" => TransportKind::Evloop,
            other => panic!("bad VFL_TRANSPORT {other:?} (want sim|threaded|evloop)"),
        };
    }),
    // shard-parallel aggregation: guarded — worker counts only apply
    // to the chunked pipeline, so monolithic configs are unaffected
    ("VFL_AGG_WORKERS", |c, v| {
        if c.chunk_words.is_some() {
            c.agg_workers = axis_usize("VFL_AGG_WORKERS", v);
        }
    }),
    // parallel mask expansion: applies to monolithic and chunked
    // configs alike — expansion exists on both paths
    ("VFL_EXPAND_WORKERS", |c, v| {
        c.expand_workers = axis_usize("VFL_EXPAND_WORKERS", v);
    }),
    // sharded event loop: inert on sim/threaded runs (the knob only
    // reaches `EvloopTransport`), composes with VFL_TRANSPORT=evloop
    ("VFL_EVLOOP_THREADS", |c, v| {
        c.evloop_threads = axis_usize("VFL_EVLOOP_THREADS", v);
    }),
    // hierarchical fan-in tree: guarded — the tree is exact-masking
    // only (a float partial would change addition order), so the
    // Plain/SecureFloat equivalence legs keep their flat topology
    ("VFL_LEAVES", |c, v| {
        if c.security == SecurityMode::SecureExact {
            c.leaves = Some(axis_usize("VFL_LEAVES", v));
        }
    }),
];

/// Apply every set CI environment axis to a config, in [`ENV_AXES`]
/// table order. Every fixture-built run flows through this once;
/// suites that reshape the config afterwards (e.g. turning on
/// chunking) re-apply it so shape-guarded axes take effect.
pub fn apply_env_axes(mut c: RunConfig) -> RunConfig {
    for (name, apply) in ENV_AXES {
        if let Ok(v) = std::env::var(name) {
            apply(&mut c, &v);
        }
    }
    c
}

/// The SIMD ISA this test process dispatches to — "scalar" under the
/// `VFL_SIMD=off` CI axis, "avx2"/"neon" where the hardware has them.
/// Suites that assert SIMD ≡ scalar log it so a CI leg that silently
/// probed scalar (and therefore proved nothing new) is visible.
pub fn simd_isa() -> &'static str {
    vfl::crypto::simd::active_isa().name()
}

/// A dropout-tolerant banking run (5 clients: 1 active + 4 passive):
/// SecureExact, Shamir threshold `t`, optional fault plan.
pub fn dropout_cfg(t: usize, plan: Option<FaultPlan>, transport: TransportKind) -> RunConfig {
    let mut c = run_cfg("banking", SecurityMode::SecureExact, transport);
    c.shamir_threshold = Some(t);
    c.fault_plan = plan;
    // shrink the threaded dropout-detection window: rounds take
    // milliseconds here, and each declared dropout otherwise sleeps
    // through full 500 ms quiescence windows
    c.stall_timeout_ms = Some(100);
    c
}

/// `n` fully set-up SA client sessions with deterministic keys.
pub fn sessions(n: usize, seed: u64) -> Vec<ClientSession> {
    let mut rng = DetRng::from_seed(seed);
    setup_all(n, 0, &mut rng)
}

/// encode ∘ decode = id for one protocol message.
pub fn assert_msg_roundtrip(m: &Msg) {
    let enc = m.encode();
    assert_eq!(&Msg::decode(&enc).unwrap(), m, "roundtrip failed for {m:?}");
}

/// Table-2 byte counters identical across two runs, per (phase, node,
/// direction).
pub fn assert_table2_identical(a: &Network, b: &Network) {
    assert_eq!(a.n_clients(), b.n_clients());
    assert_eq!(a.messages, b.messages, "message counts differ");
    let phases = [Phase::Setup, Phase::Training, Phase::Testing];
    let mut nodes = vec![Addr::Aggregator];
    nodes.extend((0..a.n_clients()).map(Addr::Client));
    for ph in phases {
        for &n in &nodes {
            assert_eq!(
                a.sent_bytes(n, ph),
                b.sent_bytes(n, ph),
                "sent bytes differ at {n:?}/{ph:?}"
            );
            assert_eq!(
                a.received_bytes(n, ph),
                b.received_bytes(n, ph),
                "received bytes differ at {n:?}/{ph:?}"
            );
        }
    }
}

/// Bit-identity of everything a run reports: losses, predictions,
/// labels, accuracy, final parameters, setup count.
pub fn assert_reports_identical(a: &RunReport, b: &RunReport, what: &str) {
    assert_eq!(a.losses, b.losses, "{what}: losses must be bit-identical");
    assert_eq!(a.predictions, b.predictions, "{what}: predictions must be bit-identical");
    assert_eq!(a.prediction_labels, b.prediction_labels, "{what}: labels differ");
    assert_eq!(a.test_accuracy, b.test_accuracy, "{what}: accuracy differs");
    assert_eq!(
        a.final_params.flatten(),
        b.final_params.flatten(),
        "{what}: final parameters must be bit-identical"
    );
    assert_eq!(a.setups, b.setups, "{what}: setup counts differ");
}

/// Where `make artifacts` puts the AOT HLO programs.
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Whether the PJRT feature + artifacts are available (PJRT suites
/// skip with a clear message otherwise).
pub fn have_artifacts() -> bool {
    if !vfl::runtime::pjrt_enabled() {
        eprintln!("skipping: built without the `pjrt` feature");
        return false;
    }
    if !artifacts_dir().join("banking_global_step.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return false;
    }
    true
}
