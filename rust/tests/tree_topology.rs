//! The hierarchical fan-in tree (`--leaves L`) is bit-invisible:
//! partitioning the clients into L leaf shards and stitching partial
//! ℤ₂⁶⁴ sums at the root produces the identical run — every report
//! field and every Table-2 byte counter — as the flat topology, on
//! every transport.
//!
//! This holds because ℤ₂⁶⁴ wrap-addition commutes and associates
//! (regrouping the summands per shard changes *where* words are
//! added, never *what* is added), client↔aggregator wire traffic is
//! untouched (the leaf→root partials are internal to the aggregator
//! node in-process), and dropout recovery preserves the exact-purge
//! invariant tree-wide: the root discards partials covering a
//! declared-dropped client, the owning leaf subtracts exactly that
//! member's words and re-emits corrected.
//!
//! The dropout twins at the bottom pin the tree's failure semantics:
//! a leaf crash is indistinguishable from its whole shard crashing
//! (in-process, the leaf fold lives in the aggregator's address
//! space — there is no separate process to kill — so the twin is the
//! flat run under the identical whole-shard fault plan), and a
//! mid-stream dropout inside a pipelined window drains the window
//! identically in tree and flat runs.

mod common;

use common::{assert_reports_identical, assert_table2_identical, dropout_cfg, run_cfg};
use vfl::coordinator::{
    build, run_experiment, summarize, RunConfig, SecurityMode, TransportKind,
};
use vfl::net::{tcp, Fault, FaultPlan, StallClock};

/// A tree run config: the standard fixture with an explicit leaf
/// count. The flat baseline pins `leaves: None` explicitly so the
/// comparison stays flat-vs-tree even under the `VFL_LEAVES` CI axis.
fn tree_cfg(l: usize, transport: TransportKind) -> RunConfig {
    let mut c = run_cfg("banking", SecurityMode::SecureExact, transport);
    c.leaves = Some(l);
    c
}

fn flat_cfg(transport: TransportKind) -> RunConfig {
    let mut c = run_cfg("banking", SecurityMode::SecureExact, transport);
    c.leaves = None;
    c
}

/// The acceptance criterion, simulator leg: L ∈ {1, 2, 4} all produce
/// the flat run bit-for-bit (banking has 5 clients, so L = 4 includes
/// singleton shards).
#[test]
fn tree_identical_to_flat_sim_all_widths() {
    let flat = run_experiment(flat_cfg(TransportKind::Sim), None).unwrap();
    assert_eq!(flat.losses.len(), 6, "the baseline did real work");
    for l in [1, 2, 4] {
        let tree = run_experiment(tree_cfg(l, TransportKind::Sim), None).unwrap();
        assert_reports_identical(&flat, &tree, &format!("sim L={l}"));
        assert_table2_identical(&flat.net, &tree.net);
    }
}

#[test]
fn tree_identical_to_flat_threaded() {
    let flat = run_experiment(flat_cfg(TransportKind::Threaded), None).unwrap();
    for l in [2, 4] {
        let tree = run_experiment(tree_cfg(l, TransportKind::Threaded), None).unwrap();
        assert_reports_identical(&flat, &tree, &format!("threaded L={l}"));
        assert_table2_identical(&flat.net, &tree.net);
    }
}

#[cfg(unix)]
#[test]
fn tree_identical_to_flat_evloop() {
    let flat = run_experiment(flat_cfg(TransportKind::Evloop), None).unwrap();
    let tree = run_experiment(tree_cfg(2, TransportKind::Evloop), None).unwrap();
    assert_reports_identical(&flat, &tree, "evloop L=2");
    assert_table2_identical(&flat.net, &tree.net);
}

/// The tree composes with the streaming pipeline: leaves fold masked
/// *chunks* through their own `ChunkAssembler`s (pooled, to exercise
/// the namespaced worker-pool slots) and still match the flat chunked
/// run bit-for-bit.
#[test]
fn tree_chunked_identical_to_flat() {
    let chunked = |l: Option<usize>| {
        let mut c = flat_cfg(TransportKind::Sim);
        c.chunk_words = Some(1000);
        c.shards = 4;
        c.agg_workers = 3;
        c.leaves = l;
        c
    };
    let flat = run_experiment(chunked(None), None).unwrap();
    for l in [2, 4] {
        let tree = run_experiment(chunked(Some(l)), None).unwrap();
        assert_reports_identical(&flat, &tree, &format!("chunked L={l}"));
        assert_table2_identical(&flat.net, &tree.net);
    }
}

/// The TCP leg: a socket run hosting the tree aggregator produces the
/// same reports as the flat simulated run, and — because the leaf
/// partials are internal to the aggregator process, never metered
/// wire traffic — the identical Table-2 counters.
#[test]
fn tree_identical_to_flat_tcp() {
    let mut cfg = tree_cfg(2, TransportKind::Sim);
    cfg.train_rounds = 2; // keep the socket run short
    let mut flat = cfg.clone();
    flat.leaves = None;
    let sim = run_experiment(flat, None).unwrap();

    // bind port 0 first so there is no port race: clients connect to
    // the real port after the listener exists
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let n_clients = cfg.model.n_clients();

    let server_cfg = cfg.clone();
    let server = std::thread::spawn(move || {
        let built = build(&server_cfg, None).unwrap();
        let mut parties = built.parties;
        let aggregator = parties.remove(0); // the TreeAggregator
        drop(parties);
        let clock = StallClock::from_config(server_cfg.stall_timeout_ms, server_cfg.stall_cap_ms);
        let out = tcp::serve_on(
            listener,
            aggregator,
            &built.schedule,
            n_clients,
            clock,
            server_cfg.rounds_in_flight,
        )?;
        let summary = summarize(&built.schedule, &built.test_labels, &out.notes);
        Ok::<_, anyhow::Error>((summary, out.net))
    });

    let mut clients = Vec::new();
    for client in 0..n_clients {
        let cfg = cfg.clone();
        let addr = addr.clone();
        clients.push(std::thread::spawn(move || {
            let built = build(&cfg, None).unwrap();
            let mut parties = built.parties;
            let party = parties.remove(client + 1);
            drop(parties);
            tcp::join(&addr, client, party)
        }));
    }

    let (summary, net) = server.join().unwrap().unwrap();
    for c in clients {
        c.join().unwrap().unwrap();
    }
    assert_eq!(summary.losses, sim.losses, "TCP tree losses must match the flat sim run");
    assert_eq!(summary.predictions, sim.predictions, "TCP tree predictions must match");
    assert_eq!(summary.test_accuracy, sim.test_accuracy);
    assert_table2_identical(&sim.net, &net);
}

/// A leaf crash is whole-shard loss. In-process the leaf fold lives in
/// the aggregator's address space, so "the leaf died" and "every
/// member of its shard died" are the same observable event; the twin
/// run proves tree recovery from it matches flat recovery bit-for-bit.
/// Under `ShardMap::new(5, 2)` the second leaf owns clients 2..5 —
/// crashing all three at one round start is the leaf-crash fault.
#[test]
fn leaf_crash_recovers_like_whole_shard_dropout() {
    let plan = FaultPlan::default()
        .with(2, Fault::Crash { round: 1, after_sends: 0 })
        .with(3, Fault::Crash { round: 1, after_sends: 0 })
        .with(4, Fault::Crash { round: 1, after_sends: 0 });
    // threshold 2: the survivors {0, 1} can still reconstruct
    let mut tree = dropout_cfg(2, Some(plan.clone()), TransportKind::Sim);
    tree.leaves = Some(2);
    let mut flat = dropout_cfg(2, Some(plan), TransportKind::Sim);
    flat.leaves = None;
    let tree = run_experiment(tree, None).unwrap();
    let flat = run_experiment(flat, None).unwrap();
    assert_reports_identical(&flat, &tree, "leaf crash vs whole-shard dropout");
    assert_table2_identical(&flat.net, &tree.net);
}

/// A mid-stream dropout inside a pipelined window (W = 2): the crash
/// lands after the client's first send of the round, so one tensor is
/// already folded into its leaf when the declaration arrives — the
/// exact-purge re-emission path — and the root's WindowDrain must
/// drain the tree run's window exactly as the flat run's.
#[test]
fn mid_tree_dropout_in_pipelined_window_matches_flat() {
    let plan =
        FaultPlan::default().with(3, Fault::Crash { round: 2, after_sends: 1 });
    let mut tree = dropout_cfg(3, Some(plan.clone()), TransportKind::Sim);
    tree.leaves = Some(2);
    tree.rounds_in_flight = 2;
    let mut flat = dropout_cfg(3, Some(plan), TransportKind::Sim);
    flat.leaves = None;
    flat.rounds_in_flight = 2;
    let tree = run_experiment(tree, None).unwrap();
    let flat = run_experiment(flat, None).unwrap();
    assert_reports_identical(&flat, &tree, "mid-tree pipelined dropout");
    assert_table2_identical(&flat.net, &tree.net);
}

/// The same twins on the threaded transport, where stall probes come
/// from real quiescence timeouts rather than simulated ones.
#[test]
fn leaf_crash_recovers_like_whole_shard_dropout_threaded() {
    let plan = FaultPlan::default()
        .with(2, Fault::Crash { round: 1, after_sends: 0 })
        .with(3, Fault::Crash { round: 1, after_sends: 0 })
        .with(4, Fault::Crash { round: 1, after_sends: 0 });
    let mut tree = dropout_cfg(2, Some(plan.clone()), TransportKind::Threaded);
    tree.leaves = Some(2);
    let mut flat = dropout_cfg(2, Some(plan), TransportKind::Threaded);
    flat.leaves = None;
    let tree = run_experiment(tree, None).unwrap();
    let flat = run_experiment(flat, None).unwrap();
    assert_reports_identical(&flat, &tree, "threaded leaf crash");
    assert_table2_identical(&flat.net, &tree.net);
}

/// The distributed deployment: real `leaf` relays between the clients
/// and a *plain* root server (the topology is invisible to the root —
/// its aggregator stitches whatever mix of direct tensors and leaf
/// partials arrives). Reports must match the flat simulated run;
/// Table-2 is *not* asserted here, deliberately — the root's receive
/// counters in this deployment reflect the reduced O(L·d) fan-in,
/// which is the measured win, not a parity bug (`net::tcp::leaf`'s
/// docs; `benches/tree_fanin.rs` quantifies it).
#[test]
fn leaf_processes_match_flat_sim() {
    let mut cfg = flat_cfg(TransportKind::Sim);
    cfg.train_rounds = 2; // keep the socket run short
    let sim = run_experiment(cfg.clone(), None).unwrap();

    let n_clients = cfg.model.n_clients();
    let leaves = 2usize;
    let map = vfl::coordinator::ShardMap::new(n_clients, leaves);
    let stream = vfl::coordinator::validate_streaming(&cfg).unwrap();

    let root_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let root_addr = root_listener.local_addr().unwrap().to_string();

    let server_cfg = cfg.clone();
    let server = std::thread::spawn(move || {
        let built = build(&server_cfg, None).unwrap();
        let mut parties = built.parties;
        let aggregator = parties.remove(0); // the plain Aggregator
        drop(parties);
        let clock = StallClock::from_config(server_cfg.stall_timeout_ms, server_cfg.stall_cap_ms);
        let out = tcp::serve_on(
            root_listener,
            aggregator,
            &built.schedule,
            n_clients,
            clock,
            server_cfg.rounds_in_flight,
        )?;
        Ok::<_, anyhow::Error>(summarize(&built.schedule, &built.test_labels, &out.notes))
    });

    // one relay thread per leaf, each on its own port
    let mut leaf_addrs = Vec::new();
    let mut leaf_threads = Vec::new();
    for k in 0..leaves {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        leaf_addrs.push(listener.local_addr().unwrap().to_string());
        let (start, end) = map.range(k);
        let root_addr = root_addr.clone();
        let stream = stream;
        leaf_threads.push(std::thread::spawn(move || {
            tcp::leaf_on(listener, &root_addr, k, start, end, &stream, false)
        }));
    }

    // every client joins its owning leaf, not the root
    let mut clients = Vec::new();
    for client in 0..n_clients {
        let cfg = cfg.clone();
        let addr = leaf_addrs[map.owner(client as u16)].clone();
        clients.push(std::thread::spawn(move || {
            let built = build(&cfg, None).unwrap();
            let mut parties = built.parties;
            let party = parties.remove(client + 1);
            drop(parties);
            tcp::join(&addr, client, party)
        }));
    }

    let summary = server.join().unwrap().unwrap();
    for c in clients {
        c.join().unwrap().unwrap();
    }
    for l in leaf_threads {
        l.join().unwrap().unwrap();
    }
    assert_eq!(summary.losses, sim.losses, "leaf-process losses must match the flat sim run");
    assert_eq!(summary.predictions, sim.predictions, "leaf-process predictions must match");
    assert_eq!(summary.test_accuracy, sim.test_accuracy);
}
