//! The tentpole invariant of the Party/Transport redesign: the *same*
//! party state machines produce **bit-identical** runs whether the
//! protocol is pumped by the single-threaded byte-metered simulator,
//! by one OS thread per party, or by the readiness-driven socket
//! event loop.
//!
//! This holds because (a) every party owns a deterministic RNG keyed
//! by (seed, client index), (b) the aggregator buffers fan-ins by
//! sender and sums in client order — so float addition order doesn't
//! depend on thread scheduling — and (c) rounds are serialized on the
//! active party's RoundDone note. Byte counters must match too: all
//! transports meter the same message encodings through `Network`.

mod common;

use common::{assert_reports_identical, assert_table2_identical, run_cfg as cfg};
use vfl::coordinator::{run_experiment, SecurityMode, TransportKind};

fn assert_bit_identical(dataset: &str, mode: SecurityMode) {
    let sim = run_experiment(cfg(dataset, mode, TransportKind::Sim), None).unwrap();
    let thr = run_experiment(cfg(dataset, mode, TransportKind::Threaded), None).unwrap();

    assert_reports_identical(&sim, &thr, &format!("{dataset}/{mode:?}"));
    assert_table2_identical(&sim.net, &thr.net);
    // sanity: the run did real work
    assert_eq!(sim.losses.len(), 6);
    assert!(!sim.predictions.is_empty());
}

#[test]
fn sim_and_threaded_identical_secure_exact() {
    assert_bit_identical("banking", SecurityMode::SecureExact);
}

#[test]
fn sim_and_threaded_identical_secure_float() {
    // float masks are the hard case: cancellation depends on addition
    // order, which the aggregator pins to client order
    assert_bit_identical("banking", SecurityMode::SecureFloat);
}

#[test]
fn sim_and_threaded_identical_plain() {
    assert_bit_identical("banking", SecurityMode::Plain);
}

#[test]
fn sim_and_threaded_identical_adult() {
    assert_bit_identical("adult", SecurityMode::SecureExact);
}

/// Sim vs evloop over real localhost sockets: every report field and
/// Table-2 counter bit-identical, for the float-mask hard case too.
#[cfg(unix)]
fn assert_evloop_bit_identical(dataset: &str, mode: SecurityMode) {
    let sim = run_experiment(cfg(dataset, mode, TransportKind::Sim), None).unwrap();
    let ev = run_experiment(cfg(dataset, mode, TransportKind::Evloop), None).unwrap();
    assert_reports_identical(&sim, &ev, &format!("{dataset}/{mode:?}/evloop"));
    assert_table2_identical(&sim.net, &ev.net);
}

#[cfg(unix)]
#[test]
fn sim_and_evloop_identical_secure_exact() {
    assert_evloop_bit_identical("banking", SecurityMode::SecureExact);
}

#[cfg(unix)]
#[test]
fn sim_and_evloop_identical_secure_float() {
    assert_evloop_bit_identical("banking", SecurityMode::SecureFloat);
}

#[test]
fn threaded_rotation_every_round() {
    let mut sc = cfg("banking", SecurityMode::SecureExact, TransportKind::Sim);
    sc.model.rotation_period = 1;
    let mut tc = cfg("banking", SecurityMode::SecureExact, TransportKind::Threaded);
    tc.model.rotation_period = 1;
    let sim = run_experiment(sc, None).unwrap();
    let thr = run_experiment(tc, None).unwrap();
    assert_eq!(sim.setups, 7, "initial + one rotation per round");
    assert_eq!(thr.setups, 7);
    assert_eq!(sim.predictions, thr.predictions);
    assert_table2_identical(&sim.net, &thr.net);
}

#[test]
fn threaded_run_trains() {
    // the threaded transport is a real training run, not just a relay
    let r = run_experiment(
        cfg("banking", SecurityMode::SecureExact, TransportKind::Threaded),
        None,
    )
    .unwrap();
    assert!(
        r.losses.last().unwrap() < r.losses.first().unwrap(),
        "loss should decrease: {:?}",
        r.losses
    );
    assert!(r.test_accuracy > 0.3, "accuracy {}", r.test_accuracy);
}
