//! Property-style tests (proptest is not vendored in this sandbox, so
//! these are driven by the in-crate deterministic ChaCha20 RNG with
//! many iterations — same idea, reproducible seeds).

mod common;

use common::assert_msg_roundtrip;
use vfl::coordinator::messages::{Msg, WireKeys};
use vfl::coordinator::parties::GradLayout;
use vfl::crypto::rng::DetRng;
use vfl::crypto::{prg, shamir};
use vfl::data::{encode, generate, partition, Feature, GroupSpec, PartitionSpec, Schema};
use vfl::model::ModelConfig;
use vfl::net::wire::{Reader, Writer};
use vfl::secagg::{aggregate, setup_all, FixedPoint};

const ITERS: usize = 200;

/// Wire primitives: encode ∘ decode = id for arbitrary payloads.
#[test]
fn prop_wire_roundtrip() {
    let mut rng = DetRng::from_seed(1);
    for _ in 0..ITERS {
        let nf = rng.next_range(0, 50) as usize;
        let f32s: Vec<f32> = (0..nf).map(|_| rng.next_f64() as f32 * 1e3 - 500.0).collect();
        let nu = rng.next_range(0, 50) as usize;
        let u64s: Vec<u64> = (0..nu).map(|_| rng.next_u64()).collect();
        let nb = rng.next_range(0, 100) as usize;
        let mut bytes = vec![0u8; nb];
        rng.fill(&mut bytes);

        let mut w = Writer::new();
        w.f32s(&f32s);
        w.u64s(&u64s);
        w.bytes(&bytes);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.f32s().unwrap(), f32s);
        assert_eq!(r.u64s().unwrap(), u64s);
        assert_eq!(r.bytes().unwrap(), bytes);
        assert!(r.done());
    }
}

/// Random bytes must never panic the message decoder (it may error).
#[test]
fn prop_msg_decode_never_panics() {
    let mut rng = DetRng::from_seed(2);
    for _ in 0..2000 {
        let n = rng.next_range(0, 200) as usize;
        let mut buf = vec![0u8; n];
        rng.fill(&mut buf);
        let _ = Msg::decode(&buf); // Result, not panic
    }
    // truncations of a valid message must also be handled
    let m = Msg::MaskedActivation { round: 1, from: 2, words: vec![1, 2, 3, 4] };
    let enc = m.encode();
    for cut in 0..enc.len() {
        let _ = Msg::decode(&enc[..cut]);
    }
}

/// Message roundtrip with randomized contents.
#[test]
fn prop_msg_roundtrip_randomized() {
    let mut rng = DetRng::from_seed(3);
    for _ in 0..ITERS {
        let n = rng.next_range(0, 20) as usize;
        let words: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let m = Msg::MaskedGradient {
            round: rng.next_u32(),
            from: rng.next_range(0, 100) as u16,
            words: words.clone(),
        };
        assert_msg_roundtrip(&m);
        assert_msg_roundtrip(&Msg::MaskedChunk {
            round: rng.next_u32(),
            from: rng.next_range(0, 100) as u16,
            tag: rng.next_range(0, 2) as u8,
            shard: rng.next_range(0, 64) as u16,
            offset: rng.next_u32(),
            total: rng.next_u32(),
            words,
        });

        let keys: Vec<Option<[u8; 32]>> = (0..rng.next_range(1, 6))
            .map(|_| {
                if rng.next_f64() < 0.3 {
                    None
                } else {
                    let mut k = [0u8; 32];
                    rng.fill(&mut k);
                    Some(k)
                }
            })
            .collect();
        let m = Msg::PublishKeys(WireKeys { from: rng.next_range(0, 10) as u16, keys });
        assert_msg_roundtrip(&m);

        // dropout-tolerance messages with randomized payloads
        let nb = rng.next_range(0, 5) as usize;
        let sealed: Vec<Vec<u8>> = (0..nb)
            .map(|_| {
                let mut b = vec![0u8; rng.next_range(0, 120) as usize];
                rng.fill(&mut b);
                b
            })
            .collect();
        let mut commitment = [0u8; 32];
        rng.fill(&mut commitment);
        assert_msg_roundtrip(&Msg::SeedShares {
            epoch: rng.next_u64(),
            from: rng.next_range(0, 16) as u16,
            commitment,
            sealed: sealed.clone(),
        });
        assert_msg_roundtrip(&Msg::ShareRelay { epoch: rng.next_u64(), sealed });
        let dropped: Vec<u16> =
            (0..rng.next_range(1, 4)).map(|_| rng.next_range(0, 16) as u16).collect();
        assert_msg_roundtrip(&Msg::DropoutNotice { round: rng.next_u32(), dropped });
    }
}

/// SA invariant: for any party count, tensor length, round and tag,
/// the masked sum equals the plain sum (within fixed-point tolerance)
/// and every proper subset stays masked.
#[test]
fn prop_secagg_sum_invariant() {
    let mut rng = DetRng::from_seed(4);
    for it in 0..40 {
        let n = rng.next_range(2, 9) as usize;
        let len = rng.next_range(1, 300) as usize;
        let round = rng.next_u64() & 0xffff;
        let tag = rng.next_u32() & 0xff;
        let sessions = setup_all(n, it as u64, &mut rng);
        let tensors: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.next_f64() as f32 * 20.0 - 10.0).collect())
            .collect();
        let masked: Vec<Vec<u64>> =
            sessions.iter().zip(&tensors).map(|(s, t)| s.mask_tensor(t, round, tag)).collect();
        let got = aggregate(&FixedPoint::default(), &masked);
        for j in 0..len {
            let want: f32 = tensors.iter().map(|t| t[j]).sum();
            assert!((got[j] - want).abs() < 1e-3, "n={n} len={len} j={j}");
        }
    }
}

/// Offset-window consistency of the seekable mask PRG: any
/// `(offset, len)` window of a [`prg::MaskStream`] — aligned to the
/// ChaCha20 block or not — equals the corresponding slice of the
/// monolithic expansion, in both mask directions.
#[test]
fn prop_mask_stream_windows_match_monolithic() {
    let mut rng = DetRng::from_seed(77);
    for _ in 0..ITERS {
        let mut ss = [0u8; 32];
        rng.fill(&mut ss);
        let len = rng.next_range(1, 400) as usize;
        let round = rng.next_u64();
        let tag = rng.next_u32();
        let (me, peer) = if rng.next_f64() < 0.5 { (0usize, 1usize) } else { (1, 0) };
        let full = prg::pairwise_mask(&ss, me, peer, round, tag, len);
        let stream = prg::MaskStream::pairwise(&ss, me, peer, round, tag);
        let off = rng.next_range(0, len as u64) as usize;
        let wlen = rng.next_range(1, (len - off) as u64 + 1) as usize;
        assert_eq!(
            stream.window(off, wlen),
            full[off..off + wlen],
            "len={len} off={off} wlen={wlen} me={me}"
        );
    }
}

/// Pairwise masks telescope for arbitrary subsets of pairs (Eq. 4 on
/// the full set; any single pair i<j cancels on its own).
#[test]
fn prop_pairwise_mask_cancellation() {
    let mut rng = DetRng::from_seed(5);
    for _ in 0..ITERS {
        let mut ss = [0u8; 32];
        rng.fill(&mut ss);
        let i = rng.next_range(0, 10) as usize;
        let j = {
            let mut j = rng.next_range(0, 10) as usize;
            while j == i {
                j = rng.next_range(0, 10) as usize;
            }
            j
        };
        let len = rng.next_range(1, 64) as usize;
        let round = rng.next_u64();
        let a = prg::pairwise_mask(&ss, i, j, round, 0, len);
        let b = prg::pairwise_mask(&ss, j, i, round, 0, len);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.wrapping_add(*y), 0);
        }
    }
}

/// Shamir: t-of-n reconstruction for random parameters and secrets,
/// with shares permuted arbitrarily.
#[test]
fn prop_shamir_reconstruction() {
    let mut rng = DetRng::from_seed(6);
    for _ in 0..100 {
        let n = rng.next_range(1, 10) as usize;
        let t = rng.next_range(1, n as u64 + 1) as usize;
        let secret = rng.next_u64() % shamir::P;
        let mut fill = DetRng::from_seed(rng.next_u64()).as_fill_fn();
        let mut shares = shamir::split(secret, t, n, &mut fill);
        // shuffle
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let shuffled: Vec<shamir::Share> = order.iter().map(|&i| shares[i]).collect();
        assert_eq!(shamir::reconstruct(&shuffled[..t]), secret, "t={t} n={n}");
        shares.clear();
    }
}

/// Fixed-point: encode/decode error bounded for random magnitudes, and
/// wrap-add homomorphism holds for random pairs.
#[test]
fn prop_fixed_point() {
    let fp = FixedPoint::default();
    let mut rng = DetRng::from_seed(7);
    for _ in 0..2000 {
        let v = (rng.next_f64() as f32 - 0.5) * 1e6;
        let r = fp.decode(fp.encode(v));
        assert!((r - v).abs() <= 1.0 / fp.scale() as f32 + v.abs() * 1e-6, "{v} {r}");
        let a = (rng.next_f64() as f32 - 0.5) * 100.0;
        let b = (rng.next_f64() as f32 - 0.5) * 100.0;
        let s = fp.decode(fp.encode(a).wrapping_add(fp.encode(b)));
        assert!((s - (a + b)).abs() < 1e-4);
    }
}

/// The documented codec bound (satellite): encode → wrap-sum → decode
/// matches the f64 reference sum within 2⁻²⁵ per element *per party*
/// (`FixedPoint::max_error`), for random party counts and magnitudes,
/// negative values included.
#[test]
fn prop_fixed_point_sum_within_documented_bound() {
    let fp = FixedPoint::default();
    let mut rng = DetRng::from_seed(42);
    for _ in 0..300 {
        let n = rng.next_range(2, 40) as usize;
        // symmetric around zero, spanning several magnitudes
        let scale_mag = 10f64.powi(rng.next_range(0, 5) as i32);
        let vals: Vec<f32> =
            (0..n).map(|_| ((rng.next_f64() - 0.5) * 2.0 * scale_mag) as f32).collect();
        let acc = vals
            .iter()
            .fold(0u64, |acc, &v| acc.wrapping_add(fp.encode(v)));
        let got = fp.decode(acc) as f64;
        let want: f64 = vals.iter().map(|&v| v as f64).sum();
        let bound = fp.max_error(n) + want.abs() * 1e-6;
        assert!(
            (got - want).abs() <= bound,
            "n={n} got={got} want={want} bound={bound}"
        );
    }
}

/// Wrap boundaries: the two's-complement encoding survives crossing
/// 2⁶³ in either direction, and exact opposites cancel to zero across
/// the wrap.
#[test]
fn fixed_point_wrap_boundaries() {
    let fp = FixedPoint::default();
    // a magnitude near the i64 clamp: encode saturates, decode returns
    // the clamped value, no UB and no sign flip
    let huge = 1e18f32;
    let enc = fp.encode(huge);
    assert!(fp.decode(enc) > 0.0, "positive clamp must stay positive");
    let enc = fp.encode(-huge);
    assert!(fp.decode(enc) < 0.0, "negative clamp must stay negative");
    // opposites cancel exactly even when each wraps past 2⁶³ with a
    // mask added (the dropout-recovery cancellation in miniature)
    let m = 0x8000_0000_0000_0001u64; // just past the sign boundary
    for v in [0.5f32, -1024.25, 3.0e6] {
        let a = fp.encode(v).wrapping_add(m);
        let b = fp.encode(-v).wrapping_add(m.wrapping_neg());
        assert_eq!(fp.decode(a.wrapping_add(b)), 0.0, "v={v}");
    }
    // a sum whose intermediate crosses the unsigned wrap decodes to the
    // correct negative total
    let a = fp.encode(-3.5);
    let b = fp.encode(1.25);
    assert_eq!(fp.decode(a.wrapping_add(b)), -2.25);
}

/// One-hot encoding: every subset view is an exact projection of the
/// full encoding, for random schemas and rows.
#[test]
fn prop_encoding_projection() {
    let mut rng = DetRng::from_seed(8);
    for it in 0..50 {
        let n_feat = rng.next_range(2, 8) as usize;
        let features: Vec<Feature> = (0..n_feat)
            .map(|i| {
                if rng.next_f64() < 0.5 {
                    Feature::cat(&format!("c{i}"), rng.next_range(2, 12) as usize)
                } else {
                    Feature::num(&format!("n{i}"), 0.0, 1.0 + rng.next_f64() as f32)
                }
            })
            .collect();
        let schema = Schema::new(&format!("s{it}"), features);
        let data = generate(&schema, 5, it as u64);
        for row in &data.rows {
            let full = encode::encode_row(&schema, row);
            assert_eq!(full.len(), schema.encoded_width());
            // random subset
            let names: Vec<&str> = schema
                .features
                .iter()
                .filter(|_| rng.next_f64() < 0.6)
                .map(|f| f.name.as_str())
                .collect();
            let sub = encode::encode_subset(&schema, row, &names);
            assert_eq!(sub.len(), schema.encoded_width_of(&names));
            // subset values appear in order within the full encoding
            let mut fi = 0usize;
            for v in &sub {
                while fi < full.len() && full[fi] != *v {
                    fi += 1;
                }
                assert!(fi < full.len(), "subset value {v} not found in order");
                fi += 1;
            }
        }
    }
}

/// Vertical partitioning: group coverage/disjointness for random specs.
#[test]
fn prop_partition_coverage() {
    let mut rng = DetRng::from_seed(9);
    for it in 0..20 {
        let schema = Schema::new(
            "p",
            vec![
                Feature::cat("a", 3),
                Feature::num("b", 0.0, 1.0),
                Feature::cat("c", 5),
                Feature::num("d", -2.0, 2.0),
                Feature::cat("e", 2),
            ],
        );
        let rows = rng.next_range(10, 200) as usize;
        let data = generate(&schema, rows, it as u64);
        let spec = PartitionSpec {
            active_features: vec!["a".into()],
            groups: vec![
                GroupSpec {
                    features: vec!["b".into(), "c".into()],
                    n_parties: rng.next_range(1, 5) as usize,
                },
                GroupSpec {
                    features: vec!["d".into(), "e".into()],
                    n_parties: rng.next_range(1, 4) as usize,
                },
            ],
        };
        let v = partition(&data, &spec);
        for g in 0..2 {
            let total: usize =
                v.passives.iter().filter(|p| p.group == g).map(|p| p.rows.len()).sum();
            assert_eq!(total, rows);
            for &id in &data.ids {
                assert!(v.holder_of(g, id).is_some());
            }
        }
    }
}

/// GradLayout: blocks tile the full vector exactly, no gaps/overlap.
#[test]
fn prop_grad_layout_tiles() {
    for ds in ["banking", "adult", "taobao"] {
        let cfg = ModelConfig::for_dataset(ds).unwrap();
        let l = GradLayout::new(&cfg);
        let mut cover = vec![0u8; l.total];
        let mut mark = |off: usize, len: usize| {
            for c in &mut cover[off..off + len] {
                *c += 1;
            }
        };
        mark(l.active_w.0, l.active_w.1);
        mark(l.active_b.0, l.active_b.1);
        for &(o, n) in &l.groups {
            mark(o, n);
        }
        assert!(cover.iter().all(|&c| c == 1), "{ds}: layout must tile exactly once");
    }
}
