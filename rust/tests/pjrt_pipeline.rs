//! Full-stack integration: the secure protocol running on the AOT
//! PJRT artifacts (L1 Pallas kernel → L2 JAX graphs → L3 coordinator).
//!
//! These tests require a `--features pjrt` build plus `make artifacts`;
//! they skip with a clear message otherwise, so `cargo test` is green
//! on a fresh checkout.

mod common;

use common::{artifacts_dir, have_artifacts};
use vfl::coordinator::{run_experiment, BackendKind, RunConfig, SecurityMode, TransportKind};
use vfl::model::ModelConfig;
use vfl::runtime::Engine;

fn cfg(dataset: &str, mode: SecurityMode, backend: BackendKind) -> RunConfig {
    let mut c = common::run_cfg(dataset, mode, TransportKind::Sim);
    c.backend = backend;
    c.train_rounds = 5;
    c
}

#[test]
fn pjrt_secure_run_matches_reference_run() {
    if !have_artifacts() {
        return;
    }
    let model = ModelConfig::for_dataset("banking").unwrap();
    let engine = Engine::load(artifacts_dir(), &model).unwrap();

    let pjrt = run_experiment(
        cfg("banking", SecurityMode::SecureExact, BackendKind::Pjrt),
        Some(&engine),
    )
    .unwrap();
    let refr =
        run_experiment(cfg("banking", SecurityMode::SecureExact, BackendKind::Reference), None)
            .unwrap();

    assert_eq!(pjrt.losses.len(), refr.losses.len());
    for (i, (a, b)) in pjrt.losses.iter().zip(&refr.losses).enumerate() {
        assert!((a - b).abs() < 1e-2, "round {i}: pjrt {a} vs reference {b}");
    }
    let fa = pjrt.final_params.flatten();
    let fb = refr.final_params.flatten();
    let max_diff = fa.iter().zip(&fb).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_diff < 1e-2, "max param diff {max_diff}");
}

#[test]
fn pjrt_secure_equals_pjrt_plain() {
    if !have_artifacts() {
        return;
    }
    let model = ModelConfig::for_dataset("banking").unwrap();
    let engine = Engine::load(artifacts_dir(), &model).unwrap();
    let secure = run_experiment(
        cfg("banking", SecurityMode::SecureExact, BackendKind::Pjrt),
        Some(&engine),
    )
    .unwrap();
    let plain =
        run_experiment(cfg("banking", SecurityMode::Plain, BackendKind::Pjrt), Some(&engine))
            .unwrap();
    for (s, p) in secure.losses.iter().zip(&plain.losses) {
        assert!((s - p).abs() < 1e-3, "secure {s} vs plain {p}");
    }
    for (s, p) in secure.predictions.iter().zip(&plain.predictions) {
        assert!((s - p).abs() < 1e-3);
    }
}

#[test]
fn pjrt_all_three_datasets_train() {
    if !have_artifacts() {
        return;
    }
    for ds in ["banking", "adult", "taobao"] {
        let model = ModelConfig::for_dataset(ds).unwrap();
        let engine = Engine::load(artifacts_dir(), &model).unwrap();
        let r = run_experiment(
            cfg(ds, SecurityMode::SecureExact, BackendKind::Pjrt),
            Some(&engine),
        )
        .unwrap();
        assert_eq!(r.losses.len(), 5, "{ds}");
        assert!(r.losses.iter().all(|l| l.is_finite()), "{ds}: {:?}", r.losses);
    }
}
