//! Dropout-tolerant secure aggregation, end-to-end through the
//! Party/Transport stack, proven by the deterministic fault-injection
//! harness (`net/faulty.rs`):
//!
//! * **Recovery is exact.** A party that crashes before contributing
//!   anything is algebraically a party whose features are all zero
//!   (its masks telescope either way — the survivors' danglers are
//!   cancelled by the reconstructed seed). We assert that twin
//!   relationship *bit-for-bit* across entire training runs.
//! * **Transports agree.** The same seeded crash schedule produces
//!   bit-identical reports on `SimTransport` (quiescence = empty FIFO)
//!   and `ThreadedTransport` (quiescence = stall timeout).
//! * **Failure is typed.** Below the Shamir threshold — or when the
//!   active party dies — the run aborts with a [`DropoutError`], never
//!   a wrong aggregate.
//!
//! Banking: 5 clients (1 active + 4 passive), threshold t = 3, so any
//! schedule dropping ≤ 2 clients must recover and 3 drops must abort.

mod common;

use common::{assert_reports_identical, assert_table2_identical, dropout_cfg};
use vfl::coordinator::{build, run_experiment, summarize, RunConfig, RunReport, TransportKind};
use vfl::net::{tcp, Fault, FaultPlan, StallClock};
use vfl::secagg::DropoutError;

const T: usize = 3;

fn run(plan: Option<FaultPlan>, transport: TransportKind) -> RunReport {
    run_experiment(dropout_cfg(T, plan, transport), None).unwrap()
}

/// Run a config that must fail, returning the error.
fn run_err(cfg: RunConfig, what: &str) -> anyhow::Error {
    match run_experiment(cfg, None) {
        Ok(_) => panic!("{what}: expected an error, got a completed run"),
        Err(e) => e,
    }
}

/// Crash `clients` in round 0 right after they published keys and
/// distributed seed shares (send #2 of the rotation) — so the epoch
/// includes them, their masks dangle, and they contribute no data.
fn crash_after_setup(clients: &[usize]) -> FaultPlan {
    let mut plan = FaultPlan::default();
    for &c in clients {
        plan = plan.with(c, Fault::Crash { round: 0, after_sends: 2 });
    }
    plan
}

/// Acceptance criterion (a): with n = 5, t = 3 and ≤ 2 dropped
/// clients, the recovered aggregate — and therefore every loss, every
/// parameter, every prediction downstream of it — is bit-identical to
/// the no-dropout run in which the same clients participate but
/// contribute exactly nothing (feature rows zeroed). That twin is what
/// "correct aggregate over the survivors" *means* in ℤ₂⁶⁴.
#[test]
fn recovery_bit_identical_to_zero_contribution_twin() {
    for drops in [vec![2usize], vec![4], vec![2, 4], vec![1, 3]] {
        let plan = crash_after_setup(&drops);
        let crash = run(Some(plan.clone()), TransportKind::Sim);
        let twin = run(Some(plan.blank_twin()), TransportKind::Sim);
        assert_reports_identical(&crash, &twin, &format!("drops {drops:?} vs blank twin"));
        // the run crossed the round-5 rotation and really trained
        assert_eq!(crash.losses.len(), 6);
        assert!(crash.losses.iter().all(|l| l.is_finite()));
        assert!(crash.setups >= 3, "initial + r0 + r5 rotations");
    }
}

/// The two-stage declaration path: client 2 crashes before its round-0
/// activation, client 3 crashes right *after* sending its activation —
/// so 3 is first treated as a survivor, fails to surrender shares for
/// 2, and is declared dropped in the second stall. Its already-buffered
/// activation must be purged (the mask correction re-adds its whole
/// total mask, which is only sound if it contributed nothing), making
/// the run bit-identical to the twin where both contribute zeros.
#[test]
fn late_declared_contributor_is_purged_not_double_masked() {
    // round 0 rotates: sends are keys(1), shares(2), act(3), grad(4)
    let plan = FaultPlan::default()
        .with(2, Fault::Crash { round: 0, after_sends: 2 })
        .with(3, Fault::Crash { round: 0, after_sends: 3 });
    let crash = run(Some(plan.clone()), TransportKind::Sim);
    let twin = run(Some(plan.blank_twin()), TransportKind::Sim);
    assert_reports_identical(&crash, &twin, "late-declared contributor vs blank twin");
    let thr = run(Some(plan), TransportKind::Threaded);
    assert_reports_identical(&crash, &thr, "late-declared contributor sim vs threaded");
}

/// Acceptance criterion (c): any seeded schedule dropping ≤ 2 passive
/// clients at round starts yields bit-identical reports under the
/// simulator's deterministic quiescence and the threaded transport's
/// timeout-based detection.
#[test]
fn seeded_schedules_bit_identical_sim_vs_threaded() {
    for seed in 0..4u64 {
        let plan = FaultPlan::seeded(seed, 5, 2, 6);
        let sim = run(Some(plan.clone()), TransportKind::Sim);
        let thr = run(Some(plan.clone()), TransportKind::Threaded);
        assert_reports_identical(&sim, &thr, &format!("seeded plan {seed}: {plan:?}"));
        assert_table2_identical(&sim.net, &thr.net);
        assert_eq!(sim.losses.len(), 6, "seed {seed}");
        assert!(sim.losses.iter().all(|l| l.is_finite()), "seed {seed}");
    }
}

/// Mid-round crashes (after 1–2 sends: between the activation and
/// gradient fan-ins, or at the end of a round) exercise the
/// gradient-stage and next-round detection paths — still bit-identical
/// across transports.
#[test]
fn seeded_mid_round_crashes_recover_on_both_transports() {
    for seed in 0..3u64 {
        let plan = FaultPlan::seeded_mid_round(seed, 5, 2, 6);
        let sim = run(Some(plan.clone()), TransportKind::Sim);
        let thr = run(Some(plan.clone()), TransportKind::Threaded);
        assert_reports_identical(&sim, &thr, &format!("mid-round plan {seed}: {plan:?}"));
        assert!(sim.losses.iter().all(|l| l.is_finite()), "seed {seed}");
    }
}

/// Acceptance criterion (b): dropping 3 of 5 clients leaves 2 < t = 3
/// survivors — the run must abort with the typed below-threshold
/// error, not produce a wrong aggregate.
#[test]
fn below_threshold_aborts_with_typed_error() {
    let mut plan = FaultPlan::default();
    for c in [2usize, 3, 4] {
        plan = plan.with(c, Fault::Crash { round: 1, after_sends: 0 });
    }
    let err = run_err(
        dropout_cfg(T, Some(plan.clone()), TransportKind::Sim),
        "2 survivors < t=3 on sim",
    );
    match err.downcast_ref::<DropoutError>() {
        Some(DropoutError::BelowThreshold { survivors, threshold }) => {
            assert_eq!((*survivors, *threshold), (2, 3));
        }
        other => panic!("expected BelowThreshold, got {other:?} ({err:#})"),
    }
    // threaded runs surface the same failure through the Failed note
    let err = run_err(
        dropout_cfg(T, Some(plan), TransportKind::Threaded),
        "2 survivors < t=3 on threaded",
    );
    assert!(
        format!("{err:#}").contains("below dropout threshold"),
        "unexpected threaded error: {err:#}"
    );
}

/// The seed-share commitments pinned at setup are enforced: a
/// malicious surrenderer that corrupts its surrendered share bundles
/// makes reconstruction produce a seed that fails the dropped client's
/// commitment — the run must abort with the typed error, never apply a
/// wrong mask correction.
#[test]
fn corrupted_surrendered_share_rejected_by_commitment() {
    let plan = FaultPlan::default()
        .with(2, Fault::Crash { round: 1, after_sends: 0 })
        .with(1, Fault::CorruptShares);
    let err = run_err(
        dropout_cfg(T, Some(plan.clone()), TransportKind::Sim),
        "corrupted surrendered share on sim",
    );
    match err.downcast_ref::<DropoutError>() {
        Some(DropoutError::SeedCommitmentMismatch { client }) => assert_eq!(*client, 2),
        other => panic!("expected SeedCommitmentMismatch, got {other:?} ({err:#})"),
    }
    // threaded runs surface the same failure through the Failed note
    let err = run_err(
        dropout_cfg(T, Some(plan), TransportKind::Threaded),
        "corrupted surrendered share on threaded",
    );
    assert!(format!("{err:#}").contains("commitment"), "unexpected threaded error: {err:#}");
}

/// The active party owns labels and the SGD step: its death is
/// unrecoverable and must be reported as such.
#[test]
fn active_party_drop_aborts() {
    let plan = FaultPlan::crash_at(0, 1);
    let err = run_err(dropout_cfg(T, Some(plan), TransportKind::Sim), "active drop");
    assert!(
        matches!(err.downcast_ref::<DropoutError>(), Some(DropoutError::ActivePartyDropped)),
        "expected ActivePartyDropped, got {err:#}"
    );
}

/// Without dropout tolerance the same crash stalls the protocol — the
/// pre-existing failure mode this PR exists to fix — and the transport
/// reports it instead of hanging.
#[test]
fn crash_without_tolerance_stalls_cleanly() {
    let mut cfg = dropout_cfg(T, Some(FaultPlan::crash_at(3, 1)), TransportKind::Sim);
    cfg.shamir_threshold = None;
    let err = run_err(cfg, "crash without tolerance");
    assert!(format!("{err:#}").contains("stalled"), "got {err:#}");
}

/// A client that crashes during the *initial* setup round (before
/// publishing keys) is excluded from the epoch entirely: nobody
/// derives a secret with it, nothing dangles, no recovery is needed —
/// and the exclusion is still bit-identical to the zero-contribution
/// twin.
#[test]
fn setup_round_drop_excluded_and_twin_identical() {
    let plan = FaultPlan::crash_at(4, vfl::coordinator::SETUP_ROUND);
    let crash = run(Some(plan.clone()), TransportKind::Sim);
    let twin = run(Some(plan.blank_twin()), TransportKind::Sim);
    assert_reports_identical(&crash, &twin, "setup-round drop vs blank twin");
    let thr = run(Some(plan), TransportKind::Threaded);
    assert_reports_identical(&crash, &thr, "setup-round drop sim vs threaded");
}

/// A drop before the round-5 rotation: the re-key excludes the dropped
/// client, so post-rotation rounds need no mask correction at all —
/// and the two transports still agree bit-for-bit.
#[test]
fn rotation_after_drop_rekeys_among_survivors() {
    let plan = FaultPlan::default().with(2, Fault::Crash { round: 1, after_sends: 0 });
    let sim = run(Some(plan.clone()), TransportKind::Sim);
    let thr = run(Some(plan), TransportKind::Threaded);
    assert_reports_identical(&sim, &thr, "drop@1 then rotation@5");
    assert_eq!(sim.losses.len(), 6);
    assert!(sim.losses.iter().all(|l| l.is_finite()));
}

/// A lost message (sender alive, activation vanished) is
/// indistinguishable from a crash to the aggregator: the sender is
/// declared dropped, the round recovers, the run completes.
#[test]
fn lost_message_declares_sender_dropped() {
    let plan = FaultPlan::default().with(3, Fault::DropMsg { round: 1, nth: 0 });
    let sim = run(Some(plan.clone()), TransportKind::Sim);
    let thr = run(Some(plan), TransportKind::Threaded);
    assert_reports_identical(&sim, &thr, "lost activation");
    assert!(sim.losses.iter().all(|l| l.is_finite()));
}

/// Bounded reordering of one event's emissions (the delay fault) is
/// invisible: the §4 machines only rely on per-sender FIFO, so the
/// report — including Table-2 byte counters — matches the unfaulted
/// run exactly.
#[test]
fn delay_reordering_is_invisible() {
    let baseline = run(None, TransportKind::Sim);
    let plan = FaultPlan::default()
        .with(0, Fault::Delay { round: 1, hold: 1 })
        .with(2, Fault::Delay { round: 2, hold: 1 });
    let delayed = run(Some(plan), TransportKind::Sim);
    assert_reports_identical(&baseline, &delayed, "delay fault");
    assert_table2_identical(&baseline.net, &delayed.net);
}

/// The evloop path: the same seeded crash schedules, run through the
/// readiness-driven socket transport end to end, stay bit-identical to
/// the simulator — quiescence via poll-timeout idle probes instead of
/// channel timeouts, same declarations, same recovery.
#[cfg(unix)]
#[test]
fn evloop_recovery_matches_sim() {
    for plan in [
        FaultPlan::default().with(3, Fault::Crash { round: 1, after_sends: 0 }),
        FaultPlan::default()
            .with(2, Fault::Crash { round: 0, after_sends: 2 })
            .with(3, Fault::Crash { round: 0, after_sends: 3 }),
    ] {
        let sim = run(Some(plan.clone()), TransportKind::Sim);
        let ev = run(Some(plan.clone()), TransportKind::Evloop);
        assert_reports_identical(&sim, &ev, &format!("evloop recovery: {plan:?}"));
        assert_table2_identical(&sim.net, &ev.net);
        assert!(sim.losses.iter().all(|l| l.is_finite()));
    }
}

/// A *dead socket* is indistinguishable from a declared dropout: a
/// client whose TCP connection simply vanishes at round 1 (no Failed
/// note, no crash fault — the peer just hangs up) is detected by the
/// evloop server's idle probes, declared dropped, and recovered — and
/// the run is bit-identical to the simulated run where the same client
/// runs a declared `Crash {{ round: 1 }}` fault.
#[cfg(unix)]
#[test]
fn evloop_dead_socket_equals_declared_dropout() {
    use vfl::coordinator::{Outbox, RoundKind};
    use vfl::net::frame::Frame;
    use vfl::net::evloop;

    const DEAD: usize = 3;
    let plan = FaultPlan::default().with(DEAD, Fault::Crash { round: 1, after_sends: 0 });
    let mut cfg = dropout_cfg(T, Some(plan), TransportKind::Sim);
    cfg.train_rounds = 2; // keep the socket run short
    let sim = run_experiment(cfg.clone(), None).unwrap();

    // the socket run injects no fault at all — client DEAD's process
    // "dies" by dropping its stream when round 1 opens
    let mut cfg = cfg;
    cfg.fault_plan = None;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let n_clients = cfg.model.n_clients();

    let server_cfg = cfg.clone();
    let server = std::thread::spawn(move || {
        let built = build(&server_cfg, None).unwrap();
        let mut parties = built.parties;
        let aggregator = parties.remove(0);
        drop(parties);
        let clock = StallClock::from_config(server_cfg.stall_timeout_ms, server_cfg.stall_cap_ms);
        let out = evloop::serve_on(
            listener,
            aggregator,
            &built.schedule,
            n_clients,
            clock,
            server_cfg.rounds_in_flight,
            evloop::PollerKind::Auto,
        )?;
        Ok::<_, anyhow::Error>((summarize(&built.schedule, &built.test_labels, &out.notes), out))
    });

    let mut clients = Vec::new();
    for client in 0..n_clients {
        let cfg = cfg.clone();
        let addr = addr.clone();
        clients.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let built = build(&cfg, None).unwrap();
            let mut parties = built.parties;
            let mut party = parties.remove(client + 1);
            drop(parties);
            if client != DEAD {
                vfl::net::tcp::join(&addr, client, party)?;
                return Ok(());
            }
            // client DEAD: a hand-rolled client loop that behaves
            // normally until training round 1 opens, then hangs up
            let mut stream = std::net::TcpStream::connect(&addr)?;
            stream.set_nodelay(true).ok();
            Frame::Hello { client: client as u16 }.write_to(&mut stream)?;
            loop {
                let mut ob = Outbox::default();
                match Frame::read_from(&mut stream)? {
                    Frame::Stop => return Ok(()),
                    Frame::Round(spec) => {
                        if spec.kind == RoundKind::Train && spec.round == 1 {
                            return Ok(()); // drop the stream: the "crash"
                        }
                        party.on_round_start(&spec, &mut ob)?;
                    }
                    Frame::Msg { bytes } => {
                        let msg = vfl::coordinator::messages::Msg::decode(&bytes)?;
                        party.on_message(vfl::net::Addr::Aggregator, msg, &mut ob)?;
                    }
                    f => anyhow::bail!("unexpected frame {f:?}"),
                }
                for (to, msg) in ob.msgs {
                    assert_eq!(to, vfl::net::Addr::Aggregator);
                    Frame::Msg { bytes: msg.into_bytes() }.write_to(&mut stream)?;
                }
                for n in ob.notes {
                    Frame::Note(n).write_to(&mut stream)?;
                }
            }
        }));
    }

    let (summary, _out) = server.join().unwrap().unwrap();
    for c in clients {
        c.join().unwrap().unwrap();
    }
    assert_eq!(summary.losses, sim.losses, "dead socket must equal declared dropout");
    assert_eq!(summary.predictions, sim.predictions);
    assert_eq!(summary.test_accuracy, sim.test_accuracy);
}

/// The TCP path: a real socket run with a crashing client, detected by
/// the server's stall timeout, produces the same losses and
/// predictions as the simulated run of the identical schedule.
#[test]
fn tcp_recovery_matches_sim() {
    let plan = FaultPlan::default().with(3, Fault::Crash { round: 1, after_sends: 0 });
    let mut cfg = dropout_cfg(T, Some(plan.clone()), TransportKind::Sim);
    cfg.train_rounds = 2; // keep the socket run short
    let sim = run_experiment(cfg.clone(), None).unwrap();

    // bind port 0 first so there is no port race: clients connect to
    // the real port after the listener exists
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let n_clients = cfg.model.n_clients();

    let server_cfg = cfg.clone();
    let server = std::thread::spawn(move || {
        let built = build(&server_cfg, None).unwrap();
        let mut parties = built.parties;
        let aggregator = parties.remove(0);
        drop(parties);
        let clock = StallClock::from_config(server_cfg.stall_timeout_ms, server_cfg.stall_cap_ms);
        let out = tcp::serve_on(
            listener,
            aggregator,
            &built.schedule,
            n_clients,
            clock,
            server_cfg.rounds_in_flight,
        )?;
        Ok::<_, anyhow::Error>((summarize(&built.schedule, &built.test_labels, &out.notes), out))
    });

    let mut clients = Vec::new();
    for client in 0..n_clients {
        let cfg = cfg.clone();
        let addr = addr.clone();
        let plan = plan.clone();
        clients.push(std::thread::spawn(move || {
            let built = build(&cfg, None).unwrap();
            let mut parties = built.parties;
            let party = parties.remove(client + 1);
            drop(parties);
            let party = plan.wrap_one(client, party);
            tcp::join(&addr, client, party)
        }));
    }

    let (summary, _out) = server.join().unwrap().unwrap();
    for c in clients {
        // the crashed client's loop just discards frames until Stop,
        // so every join should return cleanly
        c.join().unwrap().unwrap();
    }
    assert_eq!(summary.losses, sim.losses, "TCP losses must match the simulated run");
    assert_eq!(summary.predictions, sim.predictions, "TCP predictions must match");
    assert_eq!(summary.test_accuracy, sim.test_accuracy);
}
