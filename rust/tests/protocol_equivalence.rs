//! Integration tests for the full §4 protocol: the paper's central
//! correctness claim is that secure aggregation does not change the
//! training outcome ("our method does not impact training performance").
//!
//! We verify it literally: a secure run and an unsecured run with the
//! same seed must produce (near-)identical losses, parameters, and
//! predictions — differing only by the fixed-point quantization the
//! masks ride on.

mod common;

use vfl::coordinator::{run_experiment, RunConfig, SecurityMode, TransportKind};

fn cfg(dataset: &str, mode: SecurityMode) -> RunConfig {
    common::run_cfg(dataset, mode, TransportKind::Sim)
}

#[test]
fn secure_exact_matches_plain_banking() {
    let secure = run_experiment(cfg("banking", SecurityMode::SecureExact), None).unwrap();
    let plain = run_experiment(cfg("banking", SecurityMode::Plain), None).unwrap();

    assert_eq!(secure.losses.len(), plain.losses.len());
    for (i, (s, p)) in secure.losses.iter().zip(&plain.losses).enumerate() {
        assert!(
            (s - p).abs() < 1e-3,
            "round {i}: secure loss {s} vs plain {p} — masks must not affect training"
        );
    }
    // final parameters agree to fixed-point tolerance
    let sf = secure.final_params.flatten();
    let pf = plain.final_params.flatten();
    let max_diff =
        sf.iter().zip(&pf).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "max param diff {max_diff}");
    // predictions agree
    for (s, p) in secure.predictions.iter().zip(&plain.predictions) {
        assert!((s - p).abs() < 1e-3, "prediction {s} vs {p}");
    }
    // and training actually happened (loss went down)
    assert!(
        secure.losses.last().unwrap() < secure.losses.first().unwrap(),
        "loss should decrease: {:?}",
        secure.losses
    );
}

#[test]
fn secure_float_matches_plain_banking() {
    let secure = run_experiment(cfg("banking", SecurityMode::SecureFloat), None).unwrap();
    let plain = run_experiment(cfg("banking", SecurityMode::Plain), None).unwrap();
    for (s, p) in secure.losses.iter().zip(&plain.losses) {
        assert!((s - p).abs() < 1e-2, "float-mask loss {s} vs plain {p}");
    }
}

#[test]
fn secure_exact_matches_plain_adult() {
    let secure = run_experiment(cfg("adult", SecurityMode::SecureExact), None).unwrap();
    let plain = run_experiment(cfg("adult", SecurityMode::Plain), None).unwrap();
    for (s, p) in secure.losses.iter().zip(&plain.losses) {
        assert!((s - p).abs() < 1e-3, "secure {s} vs plain {p}");
    }
}

#[test]
fn key_rotation_preserves_equivalence() {
    // rotate every round (K=1): maximal churn, same training outcome
    let mut c = cfg("banking", SecurityMode::SecureExact);
    c.model.rotation_period = 1;
    let secure = run_experiment(c, None).unwrap();
    let plain = run_experiment(cfg("banking", SecurityMode::Plain), None).unwrap();
    for (s, p) in secure.losses.iter().zip(&plain.losses) {
        assert!((s - p).abs() < 1e-3);
    }
    assert_eq!(secure.setups, 7, "initial + 6 rotations (one per round)");
}

#[test]
fn communication_accounting_sane() {
    use vfl::net::{Addr, Phase};
    let secure = run_experiment(cfg("banking", SecurityMode::SecureExact), None).unwrap();
    let plain = run_experiment(cfg("banking", SecurityMode::Plain), None).unwrap();

    // every party transmitted something in both phases
    for i in 0..5 {
        assert!(secure.net.transmission_bytes(Addr::Client(i), Phase::Training) > 0);
        assert!(secure.net.transmission_bytes(Addr::Client(i), Phase::Testing) > 0);
    }
    // secure transmits strictly more than plain (masks are 8B vs 4B,
    // sealed IDs carry tags)
    let st = secure.net.transmission_bytes(Addr::Client(0), Phase::Training);
    let pt = plain.net.transmission_bytes(Addr::Client(0), Phase::Training);
    assert!(st > pt, "secure {st} vs plain {pt}");
    // training moves more bytes than testing (backward pass exists)
    let tr = secure.net.transmission_bytes(Addr::Client(1), Phase::Training);
    let te = secure.net.transmission_bytes(Addr::Client(1), Phase::Testing);
    assert!(tr > te, "training {tr} vs testing {te}");
    // plain mode has no setup traffic; secure does
    assert_eq!(plain.net.transmission_bytes(Addr::Client(0), Phase::Setup), 0);
    assert!(secure.net.transmission_bytes(Addr::Client(0), Phase::Setup) > 0);
}

#[test]
fn cpu_metrics_populated_with_overhead() {
    use vfl::net::Phase;
    let secure = run_experiment(cfg("banking", SecurityMode::SecureExact), None).unwrap();
    // active party: total > overhead > 0 in training
    let t = secure.metrics.total_ms(1, Phase::Training); // node 1 = client 0
    let o = secure.metrics.overhead_ms(1, Phase::Training);
    assert!(t > 0.0 && o > 0.0 && o < t, "total {t} overhead {o}");
    // plain run has zero overhead
    let plain = run_experiment(cfg("banking", SecurityMode::Plain), None).unwrap();
    assert_eq!(plain.metrics.overhead_ms(1, Phase::Training), 0.0);
    assert!(plain.metrics.total_ms(1, Phase::Training) > 0.0);
}

#[test]
fn taobao_runs_end_to_end() {
    let r = run_experiment(cfg("taobao", SecurityMode::SecureExact), None).unwrap();
    assert_eq!(r.losses.len(), 6);
    assert!(r.losses.iter().all(|l| l.is_finite()));
    assert!(r.test_accuracy > 0.3, "accuracy {}", r.test_accuracy);
}
