//! Security-property tests for the §5.1 threat model: what each party
//! actually observes during a protocol run, and what the extensions
//! (PKI signatures, PSI alignment, dropout recovery) guarantee.

mod common;

use std::collections::HashMap;

use common::sessions;
use vfl::coordinator::parties::{open_id, seal_id};
use vfl::crypto::ed25519::SigningKey;
use vfl::crypto::psi::{run_psi, PsiGroup, PsiParty};
use vfl::crypto::rng::DetRng;
use vfl::secagg::{aggregate, setup_all, FixedPoint};

/// Honest-but-curious aggregator: individual masked activations must be
/// statistically unrelated to the plaintext; only the sum decodes.
#[test]
fn aggregator_view_reveals_only_the_sum() {
    let n = 5;
    let len = 256;
    let sessions = sessions(n, 1);
    let tensors: Vec<Vec<f32>> =
        (0..n).map(|i| (0..len).map(|j| (i * j % 17) as f32 * 0.25).collect()).collect();
    let masked: Vec<Vec<u64>> =
        sessions.iter().zip(&tensors).map(|(s, t)| s.mask_tensor(t, 3, 0)).collect();

    let fp = FixedPoint::default();
    // (a) individual vectors decode to noise: no element within 1.0 of
    //     its plaintext except by chance (P ≈ 2^-59 per element)
    for (m, t) in masked.iter().zip(&tensors) {
        let close = fp
            .decode_vec(m)
            .iter()
            .zip(t)
            .filter(|(d, v)| (*d - *v).abs() < 1.0)
            .count();
        assert!(close <= 2, "masked vector correlates with plaintext ({close} hits)");
    }
    // (b) pairwise partial sums (colluding aggregator + one client
    //     removed) still don't decode: masks against remaining clients dangle
    let partial: Vec<Vec<u64>> = masked[..n - 1].to_vec();
    let partial_sum = aggregate(&fp, &partial);
    let want_partial: Vec<f32> =
        (0..len).map(|j| (0..n - 1).map(|i| tensors[i][j]).sum()).collect();
    let close = partial_sum.iter().zip(&want_partial).filter(|(a, b)| (*a - *b).abs() < 1.0).count();
    assert!(close <= 2, "partial sums must stay masked");
    // (c) the full sum decodes exactly
    let full = aggregate(&fp, &masked);
    for (j, v) in full.iter().enumerate() {
        let want: f32 = (0..n).map(|i| tensors[i][j]).sum();
        assert!((v - want).abs() < 1e-3, "j={j}");
    }
}

/// The fan-in tree's mask-safety argument (`coordinator::topology`):
/// a leaf's partial ℤ₂⁶⁴ sum over its client shard stays masked by
/// every cross-shard pairwise term — pairwise masks telescope to zero
/// only in the *full* cross-client sum — so neither a leaf aggregator
/// nor a root holding fewer than all L partials sees plaintext. Only
/// the complete stitch decodes.
#[test]
fn leaf_partial_sums_stay_masked() {
    let n = 5;
    let len = 256;
    let sessions = sessions(n, 1);
    let tensors: Vec<Vec<f32>> =
        (0..n).map(|i| (0..len).map(|j| (i * j % 17) as f32 * 0.25).collect()).collect();
    let masked: Vec<Vec<u64>> =
        sessions.iter().zip(&tensors).map(|(s, t)| s.mask_tensor(t, 3, 0)).collect();

    let fp = FixedPoint::default();
    let map = vfl::coordinator::ShardMap::new(n, 2);
    let mut stitched = vec![0u64; len];
    for k in 0..2 {
        let (s, e) = map.range(k);
        // what leaf k forwards upstream: its shard members' wrap-sum
        let shard: Vec<Vec<u64>> = masked[s as usize..e as usize].to_vec();
        let mut partial = vec![0u64; len];
        for m in &shard {
            for (acc, w) in partial.iter_mut().zip(m) {
                *acc = acc.wrapping_add(*w);
            }
        }
        // the partial must not correlate with its shard's plaintext
        // sum: cross-shard pairwise masks are still dangling
        let want: Vec<f32> = (0..len)
            .map(|j| (s as usize..e as usize).map(|i| tensors[i][j]).sum())
            .collect();
        let close = fp
            .decode_vec(&partial)
            .iter()
            .zip(&want)
            .filter(|(d, v)| (*d - *v).abs() < 1.0)
            .count();
        assert!(close <= 2, "leaf {k}'s partial correlates with plaintext ({close} hits)");
        for (acc, w) in stitched.iter_mut().zip(&partial) {
            *acc = acc.wrapping_add(*w);
        }
    }
    // the root's stitch of all L partials is the full sum: exact
    let full = fp.decode_vec(&stitched);
    for (j, v) in full.iter().enumerate() {
        let want: f32 = (0..n).map(|i| tensors[i][j]).sum();
        assert!((v - want).abs() < 1e-3, "j={j}");
    }
}

/// Mini-batch privacy (§4.0.2): a passive party can decrypt only the
/// sample IDs it holds; other parties' entries are indistinguishable.
#[test]
fn batch_ids_readable_only_by_holder() {
    let sessions = sessions(3, 2); // active=0, passives 1, 2
    let ids_for_1 = [11u64, 12, 13];
    let ids_for_2 = [21u64, 22];

    let mut entries = Vec::new();
    let mut seq = 0u32;
    for &id in &ids_for_1 {
        entries.push((seq, seal_id(&sessions[0].channel_key(1), 0, seq, id)));
        seq += 1;
    }
    for &id in &ids_for_2 {
        entries.push((seq, seal_id(&sessions[0].channel_key(2), 0, seq, id)));
        seq += 1;
    }

    // party 1 can open exactly its ids
    let opened_1: Vec<u64> = entries
        .iter()
        .filter_map(|(s, e)| open_id(&sessions[1].channel_key(0), 0, *s, e))
        .collect();
    assert_eq!(opened_1, ids_for_1);
    // party 2 likewise
    let opened_2: Vec<u64> = entries
        .iter()
        .filter_map(|(s, e)| open_id(&sessions[2].channel_key(0), 0, *s, e))
        .collect();
    assert_eq!(opened_2, ids_for_2);
    // party 2 cannot open party 1's entries even with key reuse attempts
    let cross: Vec<u64> = entries[..3]
        .iter()
        .filter_map(|(s, e)| open_id(&sessions[2].channel_key(0), 0, *s, e))
        .collect();
    assert!(cross.is_empty());
}

/// Key rotation (§5.1): masks from different epochs are unrelated, so a
/// compromised epoch key cannot unmask earlier rounds.
#[test]
fn rotation_isolates_epochs() {
    let mut rng_a = DetRng::from_seed(3);
    let mut rng_b = DetRng::from_seed(3); // identical entropy
    let e0 = setup_all(3, 0, &mut rng_a);
    let e1 = setup_all(3, 1, &mut rng_b);
    let t = vec![1.0f32; 32];
    let m0 = e0[1].mask_tensor(&t, 5, 0);
    let m1 = e1[1].mask_tensor(&t, 5, 0);
    assert_ne!(m0, m1, "same round+tag, different epoch → different masks");
}

/// The §5.1 malicious-setting extension: PKI-signed protocol messages.
#[test]
fn pki_detects_spoofed_messages() {
    let identity: Vec<SigningKey> = (0..3u8).map(|i| SigningKey::from_seed([i; 32])).collect();
    let registry: Vec<_> = identity.iter().map(|k| k.verifying_key()).collect();

    let payload = b"MaskedActivation round=3 from=1";
    let sig = identity[1].sign(payload);
    assert!(registry[1].verify(payload, &sig));
    // an adversary replaying client 1's message as client 2 fails
    assert!(!registry[2].verify(payload, &sig));
    // tampered payload fails
    assert!(!registry[1].verify(b"MaskedActivation round=3 from=2", &sig));
}

/// Sample alignment via DH-PSI (§4.0.2's assumed substrate): the active
/// party learns which samples a passive party shares without either side
/// revealing non-intersecting IDs.
#[test]
fn psi_aligns_samples_for_batch_selection() {
    let group = PsiGroup::new();
    let mut rng = DetRng::from_seed(4).as_fill_fn();
    let active_ids: Vec<Vec<u8>> = (0..20u64).map(|i| i.to_le_bytes().to_vec()).collect();
    let passive_ids: Vec<Vec<u8>> =
        (10..25u64).map(|i| i.to_le_bytes().to_vec()).collect();
    let a = PsiParty::new(active_ids.clone(), &group, &mut rng);
    let b = PsiParty::new(passive_ids, &group, &mut rng);
    let (ia, _) = run_psi(&a, &b, &group);
    let got: Vec<u64> = ia
        .iter()
        .map(|&i| u64::from_le_bytes(active_ids[i].as_slice().try_into().unwrap()))
        .collect();
    assert_eq!(got, (10..20).collect::<Vec<u64>>());
}

/// End-to-end holder-map construction from PSI results, as the
/// coordinator consumes it.
#[test]
fn psi_builds_holder_maps() {
    let group = PsiGroup::new();
    let mut rng = DetRng::from_seed(5).as_fill_fn();
    let all: Vec<u64> = (0..12).collect();
    let active = PsiParty::new(all.iter().map(|i| i.to_le_bytes().to_vec()).collect(), &group, &mut rng);
    // two passive parties of one group hold disjoint halves
    let p1: Vec<u64> = all.iter().copied().filter(|i| i % 2 == 0).collect();
    let p2: Vec<u64> = all.iter().copied().filter(|i| i % 2 == 1).collect();
    let mut holders: HashMap<u64, usize> = HashMap::new();
    for (pid, ids) in [(1usize, &p1), (2usize, &p2)] {
        let party =
            PsiParty::new(ids.iter().map(|i| i.to_le_bytes().to_vec()).collect(), &group, &mut rng);
        let (ia, _) = run_psi(&active, &party, &group);
        for i in ia {
            let id = u64::from_le_bytes(active.ids[i].as_slice().try_into().unwrap());
            assert!(holders.insert(id, pid).is_none(), "disjoint holders");
        }
    }
    assert_eq!(holders.len(), 12);
    assert_eq!(holders[&4], 1);
    assert_eq!(holders[&5], 2);
}
