//! Bench: the hierarchical fan-in tree's per-node load (`--leaves`).
//!
//! At a fixed protocol volume (n clients × d ℤ₂⁶⁴ words per round),
//! the flat topology funnels all n·d words into the one aggregator;
//! a tree of L leaves caps every node's fan-in at
//! max((n/L)·d, L·d) — each leaf folds its shard, the root stitches
//! L partials. This harness drives the *real* fold kernels (the same
//! [`LeafAggregator`] the transports run, the same `z64` wrap-add the
//! root stitches with) over synthetic masked words, measures per-node
//! fan-in bytes and the fold/stitch critical path, verifies the
//! stitched sum is bit-identical to the flat fold, and emits
//! `BENCH_tree.json`.
//!
//! The run fails if the root's fan-in bytes do not drop below the
//! flat topology's for every L ≥ 2 — the acceptance criterion, not
//! just a data point.
//!
//!     cargo bench --bench tree_fanin

use std::io::Write;
use std::time::Instant;

use anyhow::{ensure, Result};
use vfl::coordinator::streaming::{MONO_MSG_HEADER_BYTES, PARTIAL_SUM_HEADER_BYTES};
use vfl::coordinator::{LeafAggregator, Msg, ShardMap, StreamCfg};

/// Fixed protocol volume: 64 clients × 65 536 words (32 MiB of masked
/// payload per fan-in).
const N_CLIENTS: usize = 64;
const WORDS: usize = 65_536;

/// Deterministic synthetic masked words (splitmix64): the bench
/// measures fold cost, not crypto, and identical inputs across
/// topologies are what make the bit-identity check meaningful.
fn synth_words(seed: u64, len: usize) -> Vec<u64> {
    let mut x = seed;
    (0..len)
        .map(|_| {
            x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        })
        .collect()
}

struct Row {
    leaves: usize,
    /// Words received by the root (its fan-in).
    root_words: usize,
    /// Bytes received by the root, headers included (the Table-2
    /// accounting rule: 11 B per monolithic tensor, 14 B per partial).
    root_bytes: u64,
    /// The busiest node's fan-in words: max(leaf shard volume, root).
    max_node_words: usize,
    /// Slowest single leaf fold (the tree's parallel critical path
    /// assumes one node per leaf).
    leaf_max_ms: f64,
    root_ms: f64,
}

fn flat(tensors: &[Vec<u64>]) -> (Vec<u64>, Row) {
    let t0 = Instant::now();
    let mut acc = vec![0u64; WORDS];
    for t in tensors {
        vfl::z64::wrap_add(&mut acc, t);
    }
    let root_ms = t0.elapsed().as_secs_f64() * 1e3;
    let words = N_CLIENTS * WORDS;
    let row = Row {
        leaves: 1,
        root_words: words,
        root_bytes: N_CLIENTS as u64 * (MONO_MSG_HEADER_BYTES + 8 * WORDS as u64),
        max_node_words: words,
        leaf_max_ms: 0.0,
        root_ms,
    };
    (acc, row)
}

fn tree(tensors: &[Vec<u64>], leaves: usize) -> Result<(Vec<u64>, Row)> {
    let map = ShardMap::new(N_CLIENTS, leaves);
    let stream = StreamCfg::monolithic();
    let mut partials = Vec::new();
    let mut leaf_max_ms: f64 = 0.0;
    let mut max_shard = 0usize;
    for k in 0..leaves {
        let (s, e) = map.range(k);
        max_shard = max_shard.max((e - s) as usize * WORDS);
        let mut leaf = LeafAggregator::new(k, s, e, &stream, false, None);
        let t0 = Instant::now();
        let mut emitted = None;
        for c in s..e {
            if let Some(m) = leaf.on_masked(0, 0, c, tensors[c as usize].clone())? {
                emitted = Some(m);
            }
        }
        leaf_max_ms = leaf_max_ms.max(t0.elapsed().as_secs_f64() * 1e3);
        let Some(Msg::PartialSum { words, .. }) = emitted else {
            anyhow::bail!("leaf {k} never completed its fold");
        };
        partials.push(words);
    }
    let t0 = Instant::now();
    let mut acc = vec![0u64; WORDS];
    for p in &partials {
        vfl::z64::wrap_add(&mut acc, p);
    }
    let root_ms = t0.elapsed().as_secs_f64() * 1e3;
    let root_words = leaves * WORDS;
    let row = Row {
        leaves,
        root_words,
        root_bytes: leaves as u64 * (PARTIAL_SUM_HEADER_BYTES + 8 * WORDS as u64),
        max_node_words: max_shard.max(root_words),
        leaf_max_ms,
        root_ms,
    };
    Ok((acc, row))
}

fn json(rows: &[Row]) -> String {
    let mut out = String::from("{\n  \"tree_fanin\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"topology\": \"{}\", \"leaves\": {}, \"clients\": {}, \
             \"words_per_client\": {}, \"root_fanin_words\": {}, \"root_fanin_bytes\": {}, \
             \"max_node_fanin_words\": {}, \"leaf_fold_max_ms\": {:.3}, \
             \"root_stitch_ms\": {:.3}}}{}\n",
            if r.leaves == 1 { "flat" } else { "tree" },
            r.leaves,
            N_CLIENTS,
            WORDS,
            r.root_words,
            r.root_bytes,
            r.max_node_words,
            r.leaf_max_ms,
            r.root_ms,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() -> Result<()> {
    let tensors: Vec<Vec<u64>> =
        (0..N_CLIENTS).map(|i| synth_words(0xc0ffee ^ i as u64, WORDS)).collect();

    let (reference, flat_row) = flat(&tensors);
    let mut rows = vec![flat_row];
    for l in [2usize, 4, 8] {
        let (sum, row) = tree(&tensors, l)?;
        ensure!(sum == reference, "L={l}: stitched sum must be bit-identical to the flat fold");
        ensure!(
            row.root_bytes < rows[0].root_bytes,
            "L={l}: root fan-in ({} B) must drop below flat ({} B)",
            row.root_bytes,
            rows[0].root_bytes,
        );
        rows.push(row);
    }

    println!(
        "tree fan-in at n={N_CLIENTS} clients x d={WORDS} words ({} MiB payload):",
        N_CLIENTS * WORDS * 8 / (1 << 20)
    );
    println!(
        "{:<10} {:>16} {:>16} {:>20} {:>14} {:>14}",
        "topology", "root_words", "root_bytes", "max_node_words", "leaf_max_ms", "root_ms"
    );
    for r in &rows {
        println!(
            "{:<10} {:>16} {:>16} {:>20} {:>14.3} {:>14.3}",
            if r.leaves == 1 { "flat".to_string() } else { format!("L={}", r.leaves) },
            r.root_words,
            r.root_bytes,
            r.max_node_words,
            r.leaf_max_ms,
            r.root_ms,
        );
    }

    let path = "BENCH_tree.json";
    std::fs::File::create(path)?.write_all(json(&rows).as_bytes())?;
    println!("\nwrote {path}");
    Ok(())
}
