//! Bench: regenerate the paper's **Table 2** (data transmission in
//! bytes, active / passive × training / testing, total + overhead).
//! Byte counts are deterministic per configuration, so one secure/plain
//! pair per dataset suffices; overhead = secure − plain, the paper's
//! definition.
//!
//! Also measures the streaming pipeline's aggregator memory —
//! `peak_buffered_bytes` / `peak_shard_buffered_bytes` /
//! `peak_spilled_bytes` — against the monolithic baseline, prints the
//! table, and emits a machine-readable `BENCH_streaming.json` next to
//! the working directory so the perf trajectory has data points.
//!
//!     cargo bench --bench table2_comm
//!     (VFL_BENCH_REFERENCE=1 to skip the PJRT backend)

use std::io::Write;

use vfl::bench::tables::{self, StreamingStats};
use vfl::model::ModelConfig;
use vfl::runtime::Engine;

/// The streaming shape the memory stats are measured at.
const CHUNK_WORDS: usize = 1024;
const SHARDS: usize = 4;

fn json_escape_free(s: &str) -> &str {
    debug_assert!(!s.contains(['"', '\\']), "dataset names are plain");
    s
}

/// Hand-rolled JSON (no serde in the dependency tree): one object per
/// dataset with the streaming memory stats.
fn streaming_json(rows: &[StreamingStats]) -> String {
    let mut out = String::from("{\n  \"streaming\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let shards: Vec<String> =
            r.peak_shard_buffered.iter().map(|b| b.to_string()).collect();
        out.push_str(&format!(
            "    {{\"dataset\": \"{}\", \"chunk_words\": {}, \"shards\": {}, \
             \"mono_peak_buffered_bytes\": {}, \"peak_buffered_bytes\": {}, \
             \"peak_shard_buffered_bytes\": [{}], \"peak_spilled_bytes\": {}}}{}\n",
            json_escape_free(&r.dataset),
            r.chunk_words,
            r.shards,
            r.mono_peak_buffered,
            r.peak_buffered,
            shards.join(", "),
            r.peak_spilled,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() -> anyhow::Result<()> {
    let reference = std::env::var("VFL_BENCH_REFERENCE").is_ok();
    let mut rows = Vec::new();
    let mut streaming = Vec::new();
    for ds in ["banking", "adult", "taobao"] {
        let engine = if reference {
            None
        } else {
            Some(Engine::load("artifacts", &ModelConfig::for_dataset(ds).unwrap())?)
        };
        let (row, secure) = tables::table2_with_report(ds, engine.as_ref())?;
        rows.push(row);
        let mono_peak = secure
            .metrics
            .peak_buffered_bytes(vfl::coordinator::metrics::AGGREGATOR);
        streaming.push(tables::streaming_stats(
            ds,
            engine.as_ref(),
            CHUNK_WORDS,
            SHARDS,
            mono_peak,
        )?);
    }
    tables::print_table2(&rows);
    tables::print_streaming(&streaming);
    let json = streaming_json(&streaming);
    let path = "BENCH_streaming.json";
    std::fs::File::create(path)?.write_all(json.as_bytes())?;
    println!("\nwrote {path}");
    println!("\npaper's Table 2 for comparison (their serialization, Flower VCE):");
    println!("  Banking  active 959702/144826 train, 597762/144826 test; passive 823803/135541, 464243/135541");
    println!("  Adult    active 1031382/144826 train, 597762/144826 test; passive 895483/135541, 464243/135541");
    println!("  Taobao   active 1629142/144826 train, 925442/144826 test; passive 1493243/135541, 791923/135541");
    Ok(())
}
