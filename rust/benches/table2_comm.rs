//! Bench: regenerate the paper's **Table 2** (data transmission in
//! bytes, active / passive × training / testing, total + overhead).
//! Byte counts are deterministic per configuration, so one secure/plain
//! pair per dataset suffices; overhead = secure − plain, the paper's
//! definition.
//!
//!     cargo bench --bench table2_comm

use vfl::bench::tables;
use vfl::model::ModelConfig;
use vfl::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let reference = std::env::var("VFL_BENCH_REFERENCE").is_ok();
    let mut rows = Vec::new();
    for ds in ["banking", "adult", "taobao"] {
        let engine = if reference {
            None
        } else {
            Some(Engine::load("artifacts", &ModelConfig::for_dataset(ds).unwrap())?)
        };
        rows.push(tables::table2(ds, engine.as_ref())?);
    }
    tables::print_table2(&rows);
    println!("\npaper's Table 2 for comparison (their serialization, Flower VCE):");
    println!("  Banking  active 959702/144826 train, 597762/144826 test; passive 823803/135541, 464243/135541");
    println!("  Adult    active 1031382/144826 train, 597762/144826 test; passive 895483/135541, 464243/135541");
    println!("  Taobao   active 1629142/144826 train, 925442/144826 test; passive 1493243/135541, 791923/135541");
    Ok(())
}
