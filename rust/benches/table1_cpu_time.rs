//! Bench: regenerate the paper's **Table 1** (CPU time in ms, active /
//! passive × training / testing, total + security overhead), averaged
//! over 10 repetitions of {1 setup phase + 5 training rounds + testing}
//! with batch 256 and key rotation K=5 — the paper's §6.3 setup.
//! Emits a machine-readable `BENCH_table1.json` next to the working
//! directory so the perf trajectory has data points.
//!
//!     cargo bench --bench table1_cpu_time
//!     (VFL_BENCH_REFERENCE=1 to skip the PJRT backend,
//!      VFL_BENCH_REPS=n to change repetitions,
//!      VFL_BENCH_WINDOW=w to pipeline w rounds in flight — the
//!      per-row "pipeline:" line reports the overlap and the idle gap
//!      the window closed)

use std::io::Write;

use vfl::bench::tables::{self, Table1Row};
use vfl::bench::Stats;
use vfl::model::ModelConfig;
use vfl::runtime::Engine;

fn stats_json(s: &Stats) -> String {
    format!(
        "{{\"mean\": {:.3}, \"std\": {:.3}, \"min\": {:.3}, \"max\": {:.3}, \"n\": {}}}",
        s.mean, s.std, s.min, s.max, s.n
    )
}

/// Hand-rolled JSON (no serde in the dependency tree; same convention
/// as `BENCH_streaming.json`): one object per dataset, CPU ms as
/// mean/std/min/max over the repetitions.
fn table1_json(rows: &[Table1Row], backend: &str) -> String {
    let mut out = format!("{{\n  \"backend\": \"{backend}\",\n  \"table1\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"dataset\": \"{}\", \"window\": {}, \
             \"active_train_total_ms\": {}, \"active_train_overhead_ms\": {}, \
             \"active_test_total_ms\": {}, \"active_test_overhead_ms\": {}, \
             \"passive_train_total_ms\": {}, \"passive_train_overhead_ms\": {}, \
             \"passive_test_total_ms\": {}, \"passive_test_overhead_ms\": {}}}{}\n",
            r.dataset,
            r.window,
            stats_json(&r.active_train_total),
            stats_json(&r.active_train_overhead),
            stats_json(&r.active_test_total),
            stats_json(&r.active_test_overhead),
            stats_json(&r.passive_train_total),
            stats_json(&r.passive_train_overhead),
            stats_json(&r.passive_test_total),
            stats_json(&r.passive_test_overhead),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() -> anyhow::Result<()> {
    let reference = std::env::var("VFL_BENCH_REFERENCE").is_ok();
    let reps: usize =
        std::env::var("VFL_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    let window: usize =
        std::env::var("VFL_BENCH_WINDOW").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
    let mut rows = Vec::new();
    for ds in ["banking", "adult", "taobao"] {
        let engine = if reference {
            None
        } else {
            Some(Engine::load("artifacts", &ModelConfig::for_dataset(ds).unwrap())?)
        };
        eprintln!(
            "running {ds} ({reps} reps, backend {})...",
            if reference { "reference" } else { "pjrt" }
        );
        rows.push(tables::table1(ds, reps, engine.as_ref(), window)?);
    }
    tables::print_table1(&rows);
    let json = table1_json(&rows, if reference { "reference" } else { "pjrt" });
    let path = "BENCH_table1.json";
    std::fs::File::create(path)?.write_all(json.as_bytes())?;
    println!("\nwrote {path}");
    println!("\npaper's Table 1 for comparison (their testbed, Flower VCE):");
    println!("  Banking  active 1162±527/198±12 train, 325±15/197±12 test; passive 152±6/116±7, 139±6/114±7");
    println!("  Adult    active  814±496/202±9  train, 292±12/200±10 test; passive 165±14/120±13, 148±16/118±13");
    println!("  Taobao   active 2007±649/185±3  train, 429±7/184±3  test; passive 142±9/106±3, 127±5/105±3");
    Ok(())
}
