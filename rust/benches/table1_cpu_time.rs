//! Bench: regenerate the paper's **Table 1** (CPU time in ms, active /
//! passive × training / testing, total + security overhead), averaged
//! over 10 repetitions of {1 setup phase + 5 training rounds + testing}
//! with batch 256 and key rotation K=5 — the paper's §6.3 setup.
//!
//!     cargo bench --bench table1_cpu_time
//!     (VFL_BENCH_REFERENCE=1 to skip the PJRT backend,
//!      VFL_BENCH_REPS=n to change repetitions,
//!      VFL_BENCH_WINDOW=w to pipeline w rounds in flight — the
//!      per-row "pipeline:" line reports the overlap and the idle gap
//!      the window closed)

use vfl::bench::tables;
use vfl::model::ModelConfig;
use vfl::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let reference = std::env::var("VFL_BENCH_REFERENCE").is_ok();
    let reps: usize =
        std::env::var("VFL_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    let window: usize =
        std::env::var("VFL_BENCH_WINDOW").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
    let mut rows = Vec::new();
    for ds in ["banking", "adult", "taobao"] {
        let engine = if reference {
            None
        } else {
            Some(Engine::load("artifacts", &ModelConfig::for_dataset(ds).unwrap())?)
        };
        eprintln!(
            "running {ds} ({reps} reps, backend {})...",
            if reference { "reference" } else { "pjrt" }
        );
        rows.push(tables::table1(ds, reps, engine.as_ref(), window)?);
    }
    tables::print_table1(&rows);
    println!("\npaper's Table 1 for comparison (their testbed, Flower VCE):");
    println!("  Banking  active 1162±527/198±12 train, 325±15/197±12 test; passive 152±6/116±7, 139±6/114±7");
    println!("  Adult    active  814±496/202±9  train, 292±12/200±10 test; passive 165±14/120±13, 148±16/118±13");
    println!("  Taobao   active 2007±649/185±3  train, 429±7/184±3  test; passive 142±9/106±3, 127±5/105±3");
    Ok(())
}
