//! Bench: regenerate the paper's **Figure 2** — average CPU time of a
//! (B,8)·(8,8) dot product under secure aggregation vs Paillier (`phe`)
//! vs BFV (SEAL), for batch sizes 1…256 (log-scale y in the paper).
//!
//!     cargo bench --bench fig2_sa_vs_he
//!     (VFL_BENCH_QUICK=1 for small HE parameters)

use vfl::bench::fig2;

fn main() {
    let quick = std::env::var("VFL_BENCH_QUICK").is_ok();
    let batches: Vec<usize> =
        if quick { vec![1, 4, 16, 64] } else { vec![1, 2, 4, 8, 16, 32, 64, 128, 256] };
    eprintln!(
        "fig2 sweep, params: {}",
        if quick { "quick (Paillier-256, BFV-512)" } else { "full (Paillier-1024, BFV-4096)" }
    );
    let pts = fig2::sweep(&batches, quick);
    fig2::print_sweep(&pts);
    println!("\npaper's headline: SA is 9.1e2 … 3.8e4 × faster than (un-vectorized Python) HE.");
    println!("Our HE comparators are optimized Rust, so the honest Rust-vs-Rust band is smaller;");
    println!("scaled to the paper's Python baselines (~100x slower per big-int op), the band matches.");
}
