//! Bench: regenerate the paper's **Figure 2** — average CPU time of a
//! (B,8)·(8,8) dot product under secure aggregation vs Paillier (`phe`)
//! vs BFV (SEAL), for batch sizes 1…256 (log-scale y in the paper).
//!
//! Emits a machine-readable `BENCH_fig2.json` next to the working
//! directory so the perf trajectory has data points.
//!
//!     cargo bench --bench fig2_sa_vs_he
//!     (VFL_BENCH_QUICK=1 for small HE parameters)

use std::io::Write;

use vfl::bench::fig2::{self, Fig2Point};

/// Hand-rolled JSON (no serde in the dependency tree; same convention
/// as `BENCH_streaming.json`): one object per (scheme, batch) point.
fn fig2_json(pts: &[Fig2Point], quick: bool) -> String {
    let mut out = format!("{{\n  \"quick\": {quick},\n  \"fig2\": [\n");
    for (i, p) in pts.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"scheme\": \"{}\", \"batch\": {}, \"mean_ms\": {:.6}, \
             \"std_ms\": {:.6}, \"min_ms\": {:.6}, \"max_ms\": {:.6}, \"n\": {}}}{}\n",
            p.scheme,
            p.batch,
            p.stats.mean,
            p.stats.std,
            p.stats.min,
            p.stats.max,
            p.stats.n,
            if i + 1 < pts.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let quick = std::env::var("VFL_BENCH_QUICK").is_ok();
    let batches: Vec<usize> =
        if quick { vec![1, 4, 16, 64] } else { vec![1, 2, 4, 8, 16, 32, 64, 128, 256] };
    eprintln!(
        "fig2 sweep, params: {}",
        if quick { "quick (Paillier-256, BFV-512)" } else { "full (Paillier-1024, BFV-4096)" }
    );
    let pts = fig2::sweep(&batches, quick);
    fig2::print_sweep(&pts);
    let json = fig2_json(&pts, quick);
    let path = "BENCH_fig2.json";
    std::fs::File::create(path)
        .and_then(|mut f| f.write_all(json.as_bytes()))
        .expect("write BENCH_fig2.json");
    println!("\nwrote {path}");
    println!("\npaper's headline: SA is 9.1e2 … 3.8e4 × faster than (un-vectorized Python) HE.");
    println!("Our HE comparators are optimized Rust, so the honest Rust-vs-Rust band is smaller;");
    println!("scaled to the paper's Python baselines (~100x slower per big-int op), the band matches.");
}
