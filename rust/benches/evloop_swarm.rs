//! Bench: the event-loop transport's **C10K scaling curve** — one
//! readiness-driven aggregator thread vs a sweep of concurrent client
//! counts over real localhost sockets, up to the acceptance-criteria
//! 10 240. Each point is a full `vfl-sa swarm` run: every payload
//! frame checksummed, peak live connections and peak per-connection
//! queue depth metered, process RSS high-water mark recorded. Emits a
//! machine-readable `BENCH_evloop.json` next to the working directory
//! so the perf trajectory has data points.
//!
//! The claim the curve substantiates: wall time grows with N, but the
//! peak bytes any single connection buffers does not — per-client
//! memory is flat because per-connection state is one partial frame
//! plus one bounded outbound queue, not a thread stack.
//!
//! A second sweep holds the client count fixed and scales
//! `--evloop-threads` 1 → 8 (the token-sharded multi-loop server), so
//! the multi-core curve of the same checksum-verified workload is
//! recorded alongside (the `evloop_shards` array in the JSON).
//!
//!     cargo bench --bench evloop_swarm
//!     (VFL_BENCH_QUICK=1 for a 256/1024 sweep,
//!      VFL_BENCH_POLL=1 to pin the portable poll(2) fallback)

#[cfg(unix)]
fn main() -> anyhow::Result<()> {
    use std::io::Write;

    use vfl::net::evloop::swarm::{self, SwarmCfg, SwarmReport};
    use vfl::net::evloop::PollerKind;

    let quick = std::env::var("VFL_BENCH_QUICK").is_ok();
    let poller = if std::env::var("VFL_BENCH_POLL").is_ok() {
        PollerKind::PollFallback
    } else {
        PollerKind::Auto
    };
    let sweep: &[usize] =
        if quick { &[256, 1024] } else { &[256, 1024, 4096, 10_240] };

    let mut reports: Vec<SwarmReport> = Vec::new();
    println!(
        "{:>8} {:>10} {:>10} {:>12} {:>14} {:>12} {:>8}",
        "clients", "wall_ms", "peak_conn", "peak_buf_B", "bytes_in", "rss_kB", "poller"
    );
    for &clients in sweep {
        let cfg = SwarmCfg { clients, poller, ..SwarmCfg::default() };
        let r = swarm::run(&cfg)?;
        anyhow::ensure!(
            r.verified(),
            "swarm checksum mismatch at {clients} clients: got {:#x}, expected {:#x}",
            r.checksum,
            r.expected_checksum
        );
        println!(
            "{:>8} {:>10.1} {:>10} {:>12} {:>14} {:>12} {:>8}",
            r.clients,
            r.wall_ms,
            r.peak_live_connections,
            r.peak_conn_buffered_bytes,
            r.bytes_received,
            r.rss_peak_kb,
            r.poller
        );
        reports.push(r);
    }

    // the shard sweep: fixed client count, 1 → 8 server loops
    // (--evloop-threads), so the multi-core curve of the same checksum-
    // verified workload lands next to the client-count curve
    let shard_clients = if quick { 1024 } else { 4096 };
    let mut shard_reports: Vec<SwarmReport> = Vec::new();
    println!("\n{:>8} {:>8} {:>10} {:>10} {:>12}", "clients", "loops", "wall_ms", "peak_conn", "peak_buf_B");
    for server_threads in [1usize, 2, 4, 8] {
        let cfg =
            SwarmCfg { clients: shard_clients, server_threads, poller, ..SwarmCfg::default() };
        let r = swarm::run(&cfg)?;
        anyhow::ensure!(
            r.verified(),
            "swarm checksum mismatch at {server_threads} server loops: got {:#x}, expected {:#x}",
            r.checksum,
            r.expected_checksum
        );
        println!(
            "{:>8} {:>8} {:>10.1} {:>10} {:>12}",
            r.clients, r.server_threads, r.wall_ms, r.peak_live_connections, r.peak_conn_buffered_bytes
        );
        shard_reports.push(r);
    }

    let mut json = String::from("{\n  \"evloop_swarm\": [\n");
    for (i, r) in reports.iter().enumerate() {
        json.push_str("    ");
        json.push_str(&r.json());
        json.push_str(if i + 1 < reports.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n  \"evloop_shards\": [\n");
    for (i, r) in shard_reports.iter().enumerate() {
        json.push_str("    ");
        json.push_str(&r.json());
        json.push_str(if i + 1 < shard_reports.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_evloop.json";
    std::fs::File::create(path)?.write_all(json.as_bytes())?;
    println!("\nwrote {path}");

    // the flat-memory claim, enforced on every run of this bench: the
    // largest sweep point may not buffer more per connection than the
    // smallest, beyond one frame of slack
    if let (Some(first), Some(last)) = (reports.first(), reports.last()) {
        let slack = 4 + 1 + 6 + 8 * first.payload_words as u64;
        anyhow::ensure!(
            last.peak_conn_buffered_bytes <= first.peak_conn_buffered_bytes + slack,
            "per-connection buffering grew with client count: {} B at {} clients vs {} B at {}",
            last.peak_conn_buffered_bytes,
            last.clients,
            first.peak_conn_buffered_bytes,
            first.clients
        );
    }
    Ok(())
}

#[cfg(not(unix))]
fn main() {
    eprintln!("evloop_swarm needs a unix platform (nonblocking sockets)");
}
