//! Microbenchmarks of the hot-path primitives (the §Perf inventory):
//! mask PRG expansion, fixed-point codec, AEAD seal/open, X25519,
//! Paillier/BFV primitive ops, and the PJRT party-forward execution.
//!
//!     cargo bench --bench microbench

use std::io::Write;

use vfl::bench::{bench_ms, pm, Stats};
use vfl::crypto::aead;
use vfl::crypto::bfv::{Bfv, BfvParams};
use vfl::crypto::paillier::PrivateKey;
use vfl::crypto::prg;
use vfl::crypto::rng::DetRng;
use vfl::crypto::x25519::SecretKey;
use vfl::model::linalg::Mat;
use vfl::model::{ModelConfig, PartyParams};
use vfl::runtime::Engine;
use vfl::secagg::FixedPoint;

fn main() -> anyhow::Result<()> {
    println!("microbenchmarks (hot-path primitives)\n");
    let mut rng = DetRng::from_seed(1);

    // mask PRG: one banking activation (256×64) against 4 peers
    let secrets: Vec<(usize, [u8; 32])> = (1..5)
        .map(|i| {
            let mut s = [0u8; 32];
            rng.fill(&mut s);
            (i, s)
        })
        .collect();
    let s = bench_ms(50, || {
        std::hint::black_box(prg::total_mask(&secrets, 0, 1, 0, 256 * 64));
    });
    println!("mask PRG  256x64 vs 4 peers : {} ms", pm(&s));

    // fixed-point encode+decode of the same tensor
    let fp = FixedPoint::default();
    let vals = vec![0.123f32; 256 * 64];
    let s = bench_ms(50, || {
        let w = fp.encode_vec(&vals);
        std::hint::black_box(fp.decode_vec(&w));
    });
    println!("fixed-point codec 256x64    : {} ms", pm(&s));

    // chunked vs monolithic masking of one banking activation: the
    // streaming pipeline (encode + windowed PRG per chunk) must stay
    // within noise of the monolithic path (encode + full-mask expand)
    {
        use vfl::coordinator::streaming::{chunk_plan, ShardLayout};
        let mut srng = DetRng::from_seed(6);
        let sessions = vfl::secagg::setup_all(5, 0, &mut srng);
        let sess = &sessions[1];
        let vals = vec![0.123f32; 256 * 64];
        let s = bench_ms(50, || {
            std::hint::black_box(sess.mask_tensor(&vals, 3, 0));
        });
        println!("mask_tensor monolithic 256x64: {} ms", pm(&s));
        for (cw, shards) in [(1024usize, 4usize), (256, 16)] {
            let layout = ShardLayout::new(vals.len(), shards);
            let s = bench_ms(50, || {
                let stream = sess.total_mask_stream(3, 0);
                for c in chunk_plan(layout, cw) {
                    std::hint::black_box(sess.mask_tensor_window(
                        &stream,
                        &vals[c.offset..c.offset + c.len],
                        c.offset,
                    ));
                }
            });
            println!("mask_tensor chunked {cw:>5}w/{shards:>2}s: {} ms", pm(&s));
        }
    }

    // SIMD hot paths: scalar reference vs the runtime-dispatched
    // kernels for mask expansion (4-block ChaCha20 core) and the ℤ₂⁶⁴
    // accumulator fold, recorded to BENCH_simd.json so the words/sec
    // trajectory has data points. On hardware without a vector ISA
    // (or under VFL_SIMD=off) both legs are the scalar path and the
    // recorded speedup is ~1.
    {
        const WORDS: usize = 1 << 20;
        let isa = vfl::crypto::simd::active_isa().name();
        let mut secret = [0u8; 32];
        rng.fill(&mut secret);
        let stream = prg::MaskStream::pairwise(&secret, 0, 1, 3, 0);
        let mut buf = vec![0u64; WORDS];
        let expand_scalar = bench_ms(20, || {
            buf.iter_mut().for_each(|w| *w = 0);
            stream.add_window_scalar(0, &mut buf);
            std::hint::black_box(&buf);
        });
        let expand_simd = bench_ms(20, || {
            buf.iter_mut().for_each(|w| *w = 0);
            stream.add_window(0, &mut buf);
            std::hint::black_box(&buf);
        });
        // the fold the ChunkAssembler shard loops run: lane-chunked
        // z64 vs the pre-PR per-word zip loop
        let src: Vec<u64> =
            (0..WORDS as u64).map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15)).collect();
        let mut acc = vec![0u64; WORDS];
        let fold_naive = bench_ms(50, || {
            for (a, b) in acc.iter_mut().zip(&src) {
                *a = a.wrapping_add(*b);
            }
            std::hint::black_box(&acc);
        });
        let fold_simd = bench_ms(50, || {
            vfl::z64::wrap_add(&mut acc, &src);
            std::hint::black_box(&acc);
        });
        let mwords = |s: &Stats| (WORDS as f64 / 1.0e6) / (s.mean / 1.0e3);
        println!("mask expand 1Mi w  scalar   : {} ms ({:.1} Mwords/s)", pm(&expand_scalar), mwords(&expand_scalar));
        println!("mask expand 1Mi w  {isa:<8} : {} ms ({:.1} Mwords/s)", pm(&expand_simd), mwords(&expand_simd));
        println!("accum fold  1Mi w  naive    : {} ms ({:.1} Mwords/s)", pm(&fold_naive), mwords(&fold_naive));
        println!("accum fold  1Mi w  {isa:<8} : {} ms ({:.1} Mwords/s)", pm(&fold_simd), mwords(&fold_simd));
        // the multi-core leg: serial TotalMaskStream expansion vs the
        // ExpandPool at 1/2/4/8 workers over the same 1 Mi-word total
        // mask (4 peers, the banking federation shape) — the client's
        // per-round masking bottleneck the pool attacks
        let total = prg::TotalMaskStream::new(&secrets, 0, 1, 0);
        let mut tbuf = vec![0u64; WORDS];
        let pool_serial = bench_ms(10, || {
            tbuf.iter_mut().for_each(|w| *w = 0);
            total.add_window(0, &mut tbuf);
            std::hint::black_box(&tbuf);
        });
        let serial_ref = tbuf.clone();
        let mut pool_rows = String::new();
        println!(
            "total mask 1Mi w  serial    : {} ms ({:.1} Mwords/s)",
            pm(&pool_serial),
            mwords(&pool_serial)
        );
        for workers in [1usize, 2, 4, 8] {
            let pool = prg::ExpandPool::new(workers);
            let s = bench_ms(10, || {
                tbuf.iter_mut().for_each(|w| *w = 0);
                pool.add_window(&total, 0, &mut tbuf);
                std::hint::black_box(&tbuf);
            });
            assert_eq!(tbuf, serial_ref, "pooled expansion must be bit-identical to serial");
            println!(
                "total mask 1Mi w  pool x{workers}   : {} ms ({:.1} Mwords/s, {:.2}x)",
                pm(&s),
                mwords(&s),
                mwords(&s) / mwords(&pool_serial)
            );
            pool_rows.push_str(&format!(
                "    {{\"workers\": {workers}, \"mwords_per_s\": {:.3}, \"speedup\": {:.3}}}{}",
                mwords(&s),
                mwords(&s) / mwords(&pool_serial),
                if workers == 8 { "\n" } else { ",\n" }
            ));
        }
        // hand-rolled JSON, same convention as BENCH_fig2/BENCH_streaming
        let json = format!(
            "{{\n  \"isa\": \"{isa}\",\n  \"words\": {WORDS},\n  \
             \"mask_expand\": {{\"scalar_mwords_per_s\": {:.3}, \"dispatch_mwords_per_s\": {:.3}, \"speedup\": {:.3}}},\n  \
             \"accum_fold\": {{\"naive_mwords_per_s\": {:.3}, \"dispatch_mwords_per_s\": {:.3}, \"speedup\": {:.3}}},\n  \
             \"expand_pool\": {{\"serial_mwords_per_s\": {:.3}, \"sweep\": [\n{pool_rows}  ]}}\n}}\n",
            mwords(&expand_scalar),
            mwords(&expand_simd),
            mwords(&expand_simd) / mwords(&expand_scalar),
            mwords(&fold_naive),
            mwords(&fold_simd),
            mwords(&fold_simd) / mwords(&fold_naive),
            mwords(&pool_serial),
        );
        let path = "BENCH_simd.json";
        std::fs::File::create(path)
            .and_then(|mut f| f.write_all(json.as_bytes()))
            .expect("write BENCH_simd.json");
        println!("wrote {path}");
    }

    // AEAD: seal + trial-open of a 512-entry ID batch
    let key = [7u8; 32];
    let s = bench_ms(20, || {
        for seq in 0..512u32 {
            let n = aead::make_nonce(0, 1, seq);
            let sealed = aead::seal(&key, &n, b"aad", &(seq as u64).to_le_bytes());
            std::hint::black_box(aead::open(&key, &n, b"aad", &sealed));
        }
    });
    println!("AEAD seal+open 512 IDs      : {} ms", pm(&s));

    // X25519: one DH (per-peer setup cost)
    let sk = SecretKey::from_bytes([9u8; 32]);
    let pk = SecretKey::from_bytes([8u8; 32]).public_key();
    let s = bench_ms(20, || {
        std::hint::black_box(sk.diffie_hellman(&pk));
    });
    println!("X25519 shared secret        : {} ms", pm(&s));

    // Paillier primitive (1024-bit): encrypt + scalar-mul + decrypt
    let mut krng = DetRng::from_seed(2).as_fill_fn();
    let sk_p = PrivateKey::generate(1024, &mut krng);
    let mut erng = DetRng::from_seed(3).as_fill_fn();
    let s = bench_ms(5, || {
        let c = sk_p.public.encrypt_i64(12345, &mut erng);
        let c2 = sk_p.public.mul_plain_i64(&c, 77);
        std::hint::black_box(sk_p.decrypt_i64(&c2));
    });
    println!("Paillier-1024 enc+mul+dec   : {} ms", pm(&s));

    // BFV primitive (n=4096): encrypt + scalar-mul + decrypt
    let mut brng = DetRng::from_seed(4).as_fill_fn();
    let bfv = Bfv::keygen(BfvParams::default_4096(), &mut brng);
    let mut berng = DetRng::from_seed(5).as_fill_fn();
    let s = bench_ms(5, || {
        let c = bfv.encrypt(&bfv.encode_scalar(12345), &mut berng);
        let c2 = bfv.mul_scalar(&c, 77);
        std::hint::black_box(bfv.decode_scalar(&bfv.decrypt(&c2)));
    });
    println!("BFV-4096 enc+mul+dec        : {} ms", pm(&s));

    // PJRT party forward (banking active, batch 256)
    if std::path::Path::new("artifacts/banking_fwd_active.hlo.txt").exists() {
        let cfg = ModelConfig::for_dataset("banking").unwrap();
        let engine = Engine::load("artifacts", &cfg)?;
        let backend = vfl::coordinator::Backend::Pjrt(&engine);
        let x = Mat::from_vec(256, 57, vec![0.5; 256 * 57]);
        let params =
            PartyParams { w: Mat::from_vec(57, 64, vec![0.01; 57 * 64]), b: Some(vec![0.0; 64]) };
        let s = bench_ms(30, || {
            std::hint::black_box(backend.party_fwd("fwd_active", &x, &params, None).unwrap());
        });
        println!("PJRT fwd_active (256x57x64) : {} ms", pm(&s));
        let refb = vfl::coordinator::Backend::Reference;
        let s = bench_ms(30, || {
            std::hint::black_box(refb.party_fwd("fwd_active", &x, &params, None).unwrap());
        });
        println!("ref  fwd_active (256x57x64) : {} ms", pm(&s));
    } else {
        println!("PJRT fwd_active             : skipped (run `make artifacts`)");
    }
    Ok(())
}
