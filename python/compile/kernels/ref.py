"""Pure-jnp oracles for the Pallas kernels and the L2 model graphs.

Every kernel/graph in this package has a reference twin here; pytest
asserts allclose between the two under hypothesis-driven shape sweeps.
"""

import jax.numpy as jnp


def masked_matmul_ref(x, w, mask):
    return jnp.dot(x, w) + mask


def masked_matmul_bias_ref(x, w, bias, mask):
    return jnp.dot(x, w) + bias[None, :] + mask


def party_bwd_ref(x, dz, mask):
    return jnp.dot(x.T, dz) + mask


def global_step_ref(z, wg, bg, y):
    """Reference forward+backward of the aggregator's global module."""
    h1 = jnp.maximum(z, 0.0)
    logits = jnp.dot(h1, wg)[:, 0] + bg[0]
    # numerically stable BCE on logits
    loss = jnp.mean(
        jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    probs = 1.0 / (1.0 + jnp.exp(-logits))
    batch = z.shape[0]
    dlogit = (probs - y) / batch  # (B,)
    dwg = jnp.dot(h1.T, dlogit[:, None])  # (h, 1)
    dbg = jnp.sum(dlogit)[None]
    dh1 = dlogit[:, None] * wg[None, :, 0]  # (B, h)
    dz = jnp.where(z > 0.0, dh1, 0.0)
    return loss, probs, dz, dwg, dbg
