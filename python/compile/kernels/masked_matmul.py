"""Layer-1 Pallas kernel: fused masked matmul.

The compute hot-spot of the protocol's forward/backward passes is a
party-local ``x @ W (+ b) + mask`` (Eq. 2 / Eq. 6 of the paper): a dense
matmul immediately followed by the secure-aggregation mask addition.
Fusing the mask-add into the matmul's epilogue means the masked
activation never exists unfused in HBM — one pass, one kernel.

TPU-style design notes (DESIGN.md §Hardware-Adaptation):
  * BlockSpec tiles of (128, k) x (k, n_block) keep each grid step's
    working set ≤ ~0.5 MiB of VMEM (k ≤ 256, n ≤ 128 for every config
    in the paper), far under the ~16 MiB budget.
  * the inner ``jnp.dot`` targets the MXU with
    ``preferred_element_type=float32`` so a bf16 x/w variant would still
    accumulate in f32.
  * masks stream in through the same tiling as the output tile, so the
    HBM↔VMEM schedule is exactly one read of x, W, mask and one write.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO (see /opt/xla-example
README). The BlockSpec structure is unchanged; on a real TPU the same
code lowers to Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-row tile. 128 matches the MXU systolic dimension.
BLOCK_M = 128


def _masked_matmul_kernel(x_ref, w_ref, m_ref, o_ref):
    """o = x @ w + m for one (BLOCK_M, n) output tile."""
    o_ref[...] = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + m_ref[...]
    )


def _masked_matmul_bias_kernel(x_ref, w_ref, b_ref, m_ref, o_ref):
    """o = x @ w + b + m for one (BLOCK_M, n) output tile."""
    o_ref[...] = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...][None, :]
        + m_ref[...]
    )


@functools.partial(jax.jit, static_argnames=())
def masked_matmul(x, w, mask):
    """``x @ w + mask`` with the mask fused into the matmul epilogue.

    x: (B, k) f32 — party features (B a multiple of BLOCK_M, or ≤ it)
    w: (k, n) f32 — party weight module
    mask: (B, n) f32 — decoded secure-aggregation mask (zeros when the
          coordinator masks in the exact ℤ₂⁶⁴ domain instead)
    """
    b, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert mask.shape == (b, n)
    block_m = BLOCK_M if b % BLOCK_M == 0 else b  # odd row counts: one tile
    grid = (b // block_m,)
    return pl.pallas_call(
        _masked_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(x, w, mask)


@functools.partial(jax.jit, static_argnames=())
def masked_matmul_bias(x, w, bias, mask):
    """``x @ w + bias + mask`` (active-party variant; §6.2: only the
    active party's module is biased)."""
    b, k = x.shape
    k2, n = w.shape
    assert k == k2
    assert bias.shape == (n,)
    assert mask.shape == (b, n)
    block_m = BLOCK_M if b % BLOCK_M == 0 else b
    grid = (b // block_m,)
    return pl.pallas_call(
        _masked_matmul_bias_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(x, w, bias, mask)


def vmem_footprint_bytes(b, k, n):
    """Estimated per-grid-step VMEM working set (DESIGN.md §Perf)."""
    block_m = BLOCK_M if b % BLOCK_M == 0 else b
    return 4 * (block_m * k + k * n + 2 * block_m * n + n)
