"""AOT compile path: lower every L2 graph to HLO *text* artifacts.

Run once via ``make artifacts``; the Rust runtime loads the text with
``HloModuleProto::from_text_file`` and compiles it on its own PJRT CPU
client. HLO text (not serialized proto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-instruction-id
protos, while the text parser reassigns ids (see aot_recipe /
/opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

BATCH = 256

# Mirrors rust/src/model/config.rs (§6.2 of the paper).
DATASETS = {
    "banking": {"active_dim": 57, "group_dims": [3, 20], "hidden": 64},
    "adult": {"active_dim": 27, "group_dims": [63, 16], "hidden": 64},
    "taobao": {"active_dim": 197, "group_dims": [11, 6], "hidden": 128},
}


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_dataset(name, cfg, out_dir):
    """Lower all graphs for one dataset; returns the manifest entry."""
    b = BATCH
    h = cfg["hidden"]
    d0 = cfg["active_dim"]
    arts = {}

    def emit(key, fn, *specs):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}_{key}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        arts[key] = fname

    # active-party forward: x@w + bias + mask
    emit("fwd_active", model.party_fwd_bias, f32(b, d0), f32(d0, h), f32(h), f32(b, h))
    # active-party backward: (xT@dz + mw, sum(dz) + mb)
    emit("bwd_active", model.party_bwd_bias, f32(b, d0), f32(b, h), f32(d0, h), f32(h))
    for g, dg in enumerate(cfg["group_dims"]):
        emit(f"fwd_g{g}", model.party_fwd, f32(b, dg), f32(dg, h), f32(b, h))
        emit(f"bwd_g{g}", model.party_bwd, f32(b, dg), f32(b, h), f32(dg, h))
    # aggregator global module: fused fwd+bwd
    emit("global_step", model.global_step, f32(b, h), f32(h, 1), f32(1), f32(b))
    # testing phase: probabilities only
    emit("predict", model.predict, f32(b, h), f32(h, 1), f32(1))

    return {
        "active_dim": d0,
        "group_dims": cfg["group_dims"],
        "hidden": h,
        "artifacts": arts,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--datasets", nargs="*", default=list(DATASETS))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"batch": BATCH, "datasets": {}}
    for name in args.datasets:
        cfg = DATASETS[name]
        manifest["datasets"][name] = lower_dataset(name, cfg, args.out_dir)
        print(f"lowered {name}: {len(manifest['datasets'][name]['artifacts'])} artifacts")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['datasets'])} datasets to {args.out_dir}")


if __name__ == "__main__":
    main()
