"""Layer-2 JAX model: the per-party and global compute graphs of the
paper's VFL architecture (§3, §6.2), built on the Layer-1 Pallas kernel.

These functions are traced once by ``aot.py`` and lowered to HLO text;
the Rust coordinator executes the compiled artifacts on its PJRT client.
Python never runs on the request path.

Graphs (B = batch, d = party input width, h = hidden):
  party_fwd        (x, w, mask)        -> x@w + mask              (Eq. 2)
  party_fwd_bias   (x, w, b, mask)     -> x@w + b + mask          (active)
  party_bwd        (x, dz, mask)       -> xT@dz + mask            (Eq. 6)
  party_bwd_bias   (x, dz, mw, mb)     -> (xT@dz + mw, sum(dz) + mb)
  global_step      (z, wg, bg, y)      -> loss, probs, dz, dwg, dbg
  predict          (z, wg, bg)         -> probs                   (§4.0.3)

The ``mask`` inputs take the float-decoded secure-aggregation masks; in
the default exact-ℤ₂⁶⁴ protocol mode the coordinator passes zeros and
masks the fixed-point encoding instead (DESIGN.md §Masking).
"""

import jax
import jax.numpy as jnp

from .kernels.masked_matmul import masked_matmul, masked_matmul_bias


def party_fwd(x, w, mask):
    """Passive-party contribution to the summed embedding (Eq. 2)."""
    return masked_matmul(x, w, mask)


def party_fwd_bias(x, w, b, mask):
    """Active-party contribution (biased module, §6.2)."""
    return masked_matmul_bias(x, w, b, mask)


def party_bwd(x, dz, mask):
    """Party weight gradient given the broadcast dz (Eq. 6): xᵀ@dz."""
    # reuse the fused kernel on the transposed operand; d×B @ B×h
    return masked_matmul(x.T, dz, mask)


def party_bwd_bias(x, dz, mask_w, mask_b):
    """Active party: weight and bias gradients, both masked."""
    dw = masked_matmul(x.T, dz, mask_w)
    db = jnp.sum(dz, axis=0) + mask_b
    return dw, db


def global_step(z, wg, bg, y):
    """Aggregator global module: forward, loss, and backward.

    z:  (B, h) summed embedding (masks already cancelled)
    wg: (h, 1) global weights;  bg: (1,) bias;  y: (B,) labels
    Returns (loss, probs, dz, dwg, dbg).
    """
    h1 = jnp.maximum(z, 0.0)  # ReLU on the *summed* embedding (§6.2)
    logits = jnp.dot(h1, wg)[:, 0] + bg[0]
    loss = jnp.mean(
        jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    probs = jax.nn.sigmoid(logits)
    batch = z.shape[0]
    dlogit = (probs - y) / batch
    dwg = jnp.dot(h1.T, dlogit[:, None])
    dbg = jnp.sum(dlogit)[None]
    dh1 = dlogit[:, None] * wg[None, :, 0]
    dz = jnp.where(z > 0.0, dh1, 0.0)
    return loss, probs, dz, dwg, dbg


def predict(z, wg, bg):
    """Testing-phase forward (§4.0.3): probabilities only."""
    h1 = jnp.maximum(z, 0.0)
    logits = jnp.dot(h1, wg)[:, 0] + bg[0]
    return jax.nn.sigmoid(logits)
