"""L2 correctness: global_step gradients vs jax.grad, model shapes, and
end-to-end consistency of the lowered graphs."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestGlobalStep:
    @settings(max_examples=20, deadline=None)
    @given(b=st.integers(2, 64), h=st.integers(1, 32), seed=st.integers(0, 2**31))
    def test_matches_ref(self, b, h, seed):
        kz, kw, ky = keys(seed, 3)
        z, wg = rand(kz, b, h), rand(kw, h, 1)
        bg = jnp.array([0.1], dtype=jnp.float32)
        y = (jax.random.uniform(ky, (b,)) > 0.5).astype(jnp.float32)
        got = model.global_step(z, wg, bg, y)
        want = ref.global_step_ref(z, wg, bg, y)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(b=st.integers(2, 32), h=st.integers(1, 16), seed=st.integers(0, 2**31))
    def test_gradients_match_autodiff(self, b, h, seed):
        kz, kw, ky = keys(seed, 3)
        z, wg = rand(kz, b, h), rand(kw, h, 1)
        bg = jnp.array([-0.2], dtype=jnp.float32)
        y = (jax.random.uniform(ky, (b,)) > 0.5).astype(jnp.float32)

        def loss_fn(z, wg, bg):
            return model.global_step(z, wg, bg, y)[0]

        az, awg, abg = jax.grad(loss_fn, argnums=(0, 1, 2))(z, wg, bg)
        _, _, dz, dwg, dbg = model.global_step(z, wg, bg, y)
        np.testing.assert_allclose(dz, az, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(dwg, awg, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(dbg, abg, rtol=1e-4, atol=1e-6)

    def test_loss_decreases_under_sgd(self):
        kz, kw, ky = keys(42, 3)
        b, h = 64, 16
        z = rand(kz, b, h)
        wg = rand(kw, h, 1) * 0.1
        bg = jnp.zeros(1, dtype=jnp.float32)
        y = (z[:, 0] > 0).astype(jnp.float32)
        loss0 = None
        for _ in range(100):
            loss, probs, dz, dwg, dbg = model.global_step(z, wg, bg, y)
            if loss0 is None:
                loss0 = loss
            wg = wg - 1.0 * dwg
            bg = bg - 1.0 * dbg
        loss1 = model.global_step(z, wg, bg, y)[0]
        assert loss1 < loss0 * 0.8, f"{loss0} -> {loss1}"

    def test_predict_matches_global_step_probs(self):
        kz, kw, ky = keys(3, 3)
        z, wg = rand(kz, 32, 8), rand(kw, 8, 1)
        bg = jnp.array([0.3], dtype=jnp.float32)
        y = jnp.zeros(32, dtype=jnp.float32)
        probs_step = model.global_step(z, wg, bg, y)[1]
        probs_pred = model.predict(z, wg, bg)
        np.testing.assert_allclose(probs_pred, probs_step, rtol=1e-6)


class TestPartyGraphs:
    def test_fwd_composition_equals_centralized(self):
        # sum of party forwards == centralized x_full @ w_full (+ bias)
        k = keys(9, 6)
        b, h = 128, 16
        d0, d1, d2 = 5, 3, 4
        x0, x1, x2 = rand(k[0], b, d0), rand(k[1], b, d1), rand(k[2], b, d2)
        w0, w1, w2 = rand(k[3], d0, h), rand(k[4], d1, h), rand(k[5], d2, h)
        bias = jnp.ones(h, dtype=jnp.float32) * 0.5
        zeros = jnp.zeros((b, h))
        z = (
            model.party_fwd_bias(x0, w0, bias, zeros)
            + model.party_fwd(x1, w1, zeros)
            + model.party_fwd(x2, w2, zeros)
        )
        x_full = jnp.concatenate([x0, x1, x2], axis=1)
        w_full = jnp.concatenate([w0, w1, w2], axis=0)
        np.testing.assert_allclose(z, x_full @ w_full + bias, rtol=1e-4, atol=1e-5)

    def test_bwd_bias_sums_dz(self):
        k = keys(10, 2)
        x, dz = rand(k[0], 128, 6), rand(k[1], 128, 8)
        mw, mb = jnp.zeros((6, 8)), jnp.zeros(8)
        dw, db = model.party_bwd_bias(x, dz, mw, mb)
        np.testing.assert_allclose(dw, x.T @ dz, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(db, dz.sum(0), rtol=1e-4, atol=1e-5)

    def test_masked_bwd_masks_add(self):
        k = keys(11, 3)
        x, dz, m = rand(k[0], 128, 4), rand(k[1], 128, 8), rand(k[2], 4, 8)
        np.testing.assert_allclose(
            model.party_bwd(x, dz, m), x.T @ dz + m, rtol=1e-4, atol=1e-5
        )
