"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; every property asserts allclose against
ref.py. This is the core correctness signal for the compiled artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.masked_matmul import (
    BLOCK_M,
    masked_matmul,
    masked_matmul_bias,
    vmem_footprint_bytes,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestMaskedMatmul:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 16, 128, 256]),
        k=st.integers(1, 64),
        n=st.integers(1, 32),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref(self, b, k, n, seed):
        kx, kw, km = keys(seed, 3)
        x, w, m = rand(kx, b, k), rand(kw, k, n), rand(km, b, n)
        got = masked_matmul(x, w, m)
        want = ref.masked_matmul_ref(x, w, m)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.sampled_from([1, 4, 128, 256]),
        k=st.integers(1, 64),
        n=st.integers(1, 32),
        seed=st.integers(0, 2**31),
    )
    def test_bias_matches_ref(self, b, k, n, seed):
        kx, kw, kb, km = keys(seed, 4)
        x, w, bb, m = rand(kx, b, k), rand(kw, k, n), rand(kb, n), rand(km, b, n)
        got = masked_matmul_bias(x, w, bb, m)
        want = ref.masked_matmul_bias_ref(x, w, bb, m)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_paper_shapes(self):
        # the exact shapes the artifacts are built with
        for d, h in [(57, 64), (3, 64), (20, 64), (27, 64), (63, 64), (16, 64), (197, 128), (11, 128), (6, 128)]:
            kx, kw, km = keys(d * h, 3)
            x, w, m = rand(kx, 256, d), rand(kw, d, h), rand(km, 256, h)
            np.testing.assert_allclose(
                masked_matmul(x, w, m), ref.masked_matmul_ref(x, w, m), rtol=1e-5, atol=1e-5
            )

    def test_zero_mask_is_plain_matmul(self):
        kx, kw = keys(7, 2)
        x, w = rand(kx, 128, 16), rand(kw, 16, 8)
        got = masked_matmul(x, w, jnp.zeros((128, 8)))
        np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-6)

    def test_mask_cancellation_across_parties(self):
        # two parties with opposite masks: sum of kernel outputs == sum of matmuls
        kx1, kx2, kw1, kw2, km = keys(11, 5)
        x1, x2 = rand(kx1, 128, 8), rand(kx2, 128, 12)
        w1, w2 = rand(kw1, 8, 16), rand(kw2, 12, 16)
        m = rand(km, 128, 16)
        o1 = masked_matmul(x1, w1, m)
        o2 = masked_matmul(x2, w2, -m)
        np.testing.assert_allclose(o1 + o2, x1 @ w1 + x2 @ w2, rtol=1e-4, atol=1e-5)

    def test_grid_tiling_multiple_blocks(self):
        # batch 256 = 2 grid steps of BLOCK_M=128: outputs must be identical
        # to a single unblocked matmul
        assert BLOCK_M == 128
        kx, kw = keys(13, 2)
        x, w = rand(kx, 256, 32), rand(kw, 32, 8)
        m = jnp.zeros((256, 8))
        np.testing.assert_allclose(masked_matmul(x, w, m), x @ w, rtol=1e-5, atol=1e-5)

    def test_vmem_footprint_under_budget(self):
        # every paper config fits comfortably in 16 MiB VMEM
        for b, k, n in [(256, 57, 64), (256, 197, 128), (256, 63, 64)]:
            assert vmem_footprint_bytes(b, k, n) < 1 << 20  # < 1 MiB


class TestPartyBwd:
    @settings(max_examples=20, deadline=None)
    @given(
        b=st.sampled_from([2, 128, 256]),
        d=st.integers(1, 64),
        h=st.integers(1, 32),
        seed=st.integers(0, 2**31),
    )
    def test_bwd_matches_ref(self, b, d, h, seed):
        from compile.model import party_bwd

        kx, kz, km = keys(seed, 3)
        x, dz, m = rand(kx, b, d), rand(kz, b, h), rand(km, d, h)
        got = party_bwd(x, dz, m)
        want = ref.party_bwd_ref(x, dz, m)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
