"""AOT path: every graph lowers to parseable HLO text with the right
parameter/result shapes, for every dataset config."""

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def lower_text(fn, *specs):
    return aot.to_hlo_text(jax.jit(fn).lower(*specs))


class TestLowering:
    def test_all_datasets_lower(self, tmp_path):
        for name, cfg in aot.DATASETS.items():
            entry = aot.lower_dataset(name, cfg, str(tmp_path))
            assert len(entry["artifacts"]) == 8, name
            for fname in entry["artifacts"].values():
                text = (tmp_path / fname).read_text()
                assert "ENTRY" in text, f"{fname} must be HLO text"
                assert "ROOT" in text

    def test_hlo_is_tuple_rooted(self):
        # return_tuple=True → root is a tuple (what the Rust loader expects)
        b, d, h = 256, 57, 64
        text = lower_text(
            model.party_fwd,
            aot.f32(b, d),
            aot.f32(d, h),
            aot.f32(b, h),
        )
        assert "tuple(" in text.replace(" ", "").lower() or "(f32[256,64]{1,0})" in text

    def test_global_step_has_five_outputs(self):
        b, h = 256, 64
        text = lower_text(
            model.global_step, aot.f32(b, h), aot.f32(h, 1), aot.f32(1), aot.f32(b)
        )
        # loss scalar, probs (256), dz (256,64), dwg (64,1), dbg (1)
        assert "f32[256,64]" in text
        assert "f32[64,1]" in text

    def test_batch_constant(self):
        assert aot.BATCH == 256  # the paper's batch size

    def test_dataset_dims_match_rust(self):
        # mirror of rust/src/model/config.rs tests
        assert aot.DATASETS["banking"] == {"active_dim": 57, "group_dims": [3, 20], "hidden": 64}
        assert aot.DATASETS["adult"] == {"active_dim": 27, "group_dims": [63, 16], "hidden": 64}
        assert aot.DATASETS["taobao"] == {"active_dim": 197, "group_dims": [11, 6], "hidden": 128}


class TestManifest:
    def test_manifest_written(self, tmp_path):
        import json
        import sys

        argv = sys.argv
        sys.argv = ["aot", "--out-dir", str(tmp_path), "--datasets", "banking"]
        try:
            aot.main()
        finally:
            sys.argv = argv
        m = json.loads((tmp_path / "manifest.json").read_text())
        assert m["batch"] == 256
        assert "banking" in m["datasets"]
        assert len(m["datasets"]["banking"]["artifacts"]) == 8
