"""vflint (tools/vflint/vflint.py) must gate the tree: exit 0 on the
repo as committed, pass its fixture self-test, and actually fail when a
violation is introduced.  Stdlib-only — the analyzer itself is the
thing under test, and it must run in toolchain-free containers."""

import os
import shutil
import subprocess
import sys
import tempfile
import unittest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
VFLINT = os.path.join(REPO, "tools", "vflint", "vflint.py")


def run_vflint(*args):
    return subprocess.run(
        [sys.executable, VFLINT, *args],
        capture_output=True,
        text=True,
        timeout=120,
    )


class VflintGatesTheTree(unittest.TestCase):
    def test_repo_tree_is_clean(self):
        r = run_vflint()
        self.assertEqual(r.returncode, 0, f"vflint found violations:\n{r.stdout}{r.stderr}")
        self.assertIn("clean", r.stdout)

    def test_self_test_passes(self):
        r = run_vflint("--self-test")
        self.assertEqual(r.returncode, 0, f"fixture self-test failed:\n{r.stdout}{r.stderr}")
        self.assertIn("PASS", r.stdout)

    def test_list_checks_names_all_seven(self):
        r = run_vflint("--list-checks")
        self.assertEqual(r.returncode, 0)
        checks = r.stdout.split()
        self.assertEqual(
            checks,
            [
                "unsafe-audit",
                "no-blocking-io",
                "bounded-channels",
                "env-registry",
                "frame-encode-rule",
                "panic-discipline",
                "cfg-coverage",
            ],
        )

    def test_detects_injected_violation(self):
        # copy the tree's configs but plant a single bad file: an
        # un-inventoried unsafe block must flip the exit code to 1
        with tempfile.TemporaryDirectory() as root:
            src = os.path.join(root, "rust", "src")
            os.makedirs(src)
            with open(os.path.join(src, "lib.rs"), "w") as f:
                f.write("pub fn f(p: *const u64) -> u64 { unsafe { *p } }\n")
            r = run_vflint("--root", root)
            self.assertEqual(r.returncode, 1, f"expected failure, got:\n{r.stdout}")
            self.assertIn("unsafe-audit", r.stdout)

    def test_stale_allowlist_entry_fails(self):
        # an allowlist entry that matches nothing is itself a finding —
        # suppressions cannot silently outlive the code they excused
        with tempfile.TemporaryDirectory() as root:
            os.makedirs(os.path.join(root, "rust", "src"))
            with open(os.path.join(root, "rust", "src", "lib.rs"), "w") as f:
                f.write("pub fn ok() {}\n")
            cfg = os.path.join(root, "tools", "vflint")
            os.makedirs(cfg)
            with open(os.path.join(cfg, "allowlist.txt"), "w") as f:
                f.write("panic-discipline: rust/src/lib.rs: .unwrap() # gone\n")
            r = run_vflint("--root", root)
            self.assertEqual(r.returncode, 1, f"expected stale-entry failure, got:\n{r.stdout}")
            self.assertIn("stale", r.stdout)

    def test_fixture_corpus_covers_every_check(self):
        fixtures = os.path.join(REPO, "tools", "vflint", "fixtures")
        trees = {d for d in os.listdir(fixtures) if os.path.isdir(os.path.join(fixtures, d))}
        for check in [
            "unsafe-audit",
            "no-blocking-io",
            "bounded-channels",
            "env-registry",
            "frame-encode-rule",
            "panic-discipline",
            "cfg-coverage",
        ]:
            self.assertIn(check, trees, f"no fixture tree for {check}")
        self.assertIn("clean", trees)


if __name__ == "__main__":
    unittest.main()
