//! End-to-end driver (EXPERIMENTS.md E4): trains the Banking VFL model
//! for a few hundred rounds on the full synthetic corpus, through the
//! complete secure protocol on the PJRT artifacts, and logs the loss
//! curve plus the secure-vs-plain equivalence check.
//!
//! This is the "prove all layers compose" example: L1 Pallas kernel →
//! L2 AOT graphs → L3 coordinator with real key rotation, encrypted
//! batch selection, and masked aggregation on every step.
//!
//!     make artifacts && cargo run --release --example banking_e2e
//!     (add --reference to skip the PJRT backend, --rounds N to resize)

use vfl::coordinator::{run_experiment, BackendKind, RunConfig, SecurityMode};
use vfl::model::ModelConfig;
use vfl::net::{Addr, Phase};
use vfl::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let reference = args.iter().any(|a| a == "--reference");
    let rounds: usize = args
        .iter()
        .position(|a| a == "--rounds")
        .and_then(|i| args.get(i + 1))
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(300);

    let mut cfg = RunConfig::paper("banking").unwrap();
    cfg.n_rows = 45_211; // the real Banking row count (§6.1)
    cfg.train_rounds = rounds;
    cfg.test_rounds = 20;
    cfg.backend = if reference { BackendKind::Reference } else { BackendKind::Pjrt };

    let engine = if reference {
        None
    } else {
        Some(Engine::load("artifacts", &ModelConfig::for_dataset("banking").unwrap())?)
    };

    println!("=== banking e2e: secure run ({rounds} rounds, 45211 rows) ===");
    let t0 = std::time::Instant::now();
    let secure = run_experiment(cfg.clone(), engine.as_ref())?;
    let secure_wall = t0.elapsed().as_secs_f64();

    for (i, loss) in secure.losses.iter().enumerate() {
        if i % 20 == 0 || i + 1 == secure.losses.len() {
            println!("round {i:>4}  loss {loss:.5}");
        }
    }
    let ev = vfl::model::eval::evaluate(&secure.predictions, &secure.prediction_labels);
    println!("\nsecure: test accuracy {:.4}  AUC {:.4}  log-loss {:.4}  ({} setups, {:.1}s wall)",
        ev.accuracy, ev.auc, ev.log_loss, secure.setups, secure_wall);

    println!("\n=== unsecured twin (same seed) ===");
    let mut plain_cfg = cfg;
    plain_cfg.security = SecurityMode::Plain;
    let plain = run_experiment(plain_cfg, engine.as_ref())?;
    println!("plain:  test accuracy {:.4}", plain.test_accuracy);

    let max_loss_diff = secure
        .losses
        .iter()
        .zip(&plain.losses)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("\nmax per-round loss difference (secure − plain): {max_loss_diff:.2e}");
    println!("→ the paper's claim: secure aggregation does not impact training");
    assert!(max_loss_diff < 5e-3, "secure and plain training must agree");

    println!("\n--- per-party cost (secure run) ---");
    println!("active  train: {:>9.1} ms ({:>7.1} ms overhead)  tx {:>9} B",
        secure.metrics.total_ms(1, Phase::Training) + secure.metrics.total_ms(1, Phase::Setup),
        secure.metrics.overhead_ms(1, Phase::Training) + secure.metrics.overhead_ms(1, Phase::Setup),
        secure.net.transmission_bytes(Addr::Client(0), Phase::Training));
    for p in 1..=4 {
        println!(
            "passive{p} train: {:>8.1} ms ({:>7.1} ms overhead)  tx {:>9} B",
            secure.metrics.total_ms(p + 1, Phase::Training),
            secure.metrics.overhead_ms(p + 1, Phase::Training),
            secure.net.transmission_bytes(Addr::Client(p), Phase::Training)
        );
    }
    Ok(())
}
