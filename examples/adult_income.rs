//! Adult-income scenario (§6.1): a census-data VFL deployment where
//! demographic attributes live with two bureau-style passive parties
//! and education records with two more, while the employer-side active
//! party holds work/occupation features and the >50K label.
//!
//! Demonstrates: training with all three security modes and comparing
//! their cost/accuracy on the same data, i.e. the trade-off table a
//! deployment engineer would actually look at.
//!
//!     cargo run --release --example adult_income [-- --pjrt]

use vfl::coordinator::{run_experiment, BackendKind, RunConfig, SecurityMode};
use vfl::model::ModelConfig;
use vfl::net::{Addr, Phase};
use vfl::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let pjrt = std::env::args().any(|a| a == "--pjrt");
    let engine = if pjrt {
        Some(Engine::load("artifacts", &ModelConfig::for_dataset("adult").unwrap())?)
    } else {
        None
    };

    println!("Adult income VFL: 1 active + 4 passive parties, 106 features total\n");
    println!(
        "{:<14} {:>10} {:>12} {:>14} {:>14}",
        "mode", "accuracy", "final_loss", "active_tx_B", "active_cpu_ms"
    );

    for (name, mode) in [
        ("secure-exact", SecurityMode::SecureExact),
        ("secure-float", SecurityMode::SecureFloat),
        ("plain", SecurityMode::Plain),
    ] {
        let mut cfg = RunConfig::paper("adult").unwrap();
        cfg.n_rows = 8192;
        cfg.train_rounds = 60;
        cfg.test_rounds = 4;
        cfg.security = mode;
        cfg.backend = if pjrt { BackendKind::Pjrt } else { BackendKind::Reference };
        let report = run_experiment(cfg, engine.as_ref())?;
        println!(
            "{:<14} {:>10.4} {:>12.5} {:>14} {:>14.1}",
            name,
            report.test_accuracy,
            report.losses.last().unwrap(),
            report.net.transmission_bytes(Addr::Client(0), Phase::Training),
            report.metrics.total_ms(1, Phase::Training),
        );
    }
    println!("\n→ identical accuracy across modes; security costs only bytes/ms");
    Ok(())
}
