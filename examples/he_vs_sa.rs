//! The Figure-2 ablation as a runnable example (§6.5): secure
//! aggregation vs Paillier (`phe`) vs BFV (SEAL) on (B,8)·(8,8) dot
//! products, batch sizes 1…256, average CPU time per scheme.
//!
//! Pass --quick for small HE parameters (fast smoke run); the default
//! uses 1024-bit Paillier and n=4096 BFV.
//!
//!     cargo run --release --example he_vs_sa [-- --quick]

use vfl::bench::fig2;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let batches: Vec<usize> =
        if quick { vec![1, 4, 16, 64] } else { vec![1, 2, 4, 8, 16, 32, 64, 128, 256] };

    println!("SA vs HE dot-product ablation (paper Fig. 2)");
    println!("params: {}\n", if quick { "quick (Paillier-256, BFV-512)" } else { "full (Paillier-1024, BFV-4096)" });
    let pts = fig2::sweep(&batches, quick);
    fig2::print_sweep(&pts);

    // headline: the speedup band
    let mut min_speedup = f64::INFINITY;
    let mut max_speedup: f64 = 0.0;
    for b in &batches {
        let sa = pts.iter().find(|p| p.batch == *b && p.scheme == "SA").unwrap().stats.mean;
        for scheme in ["Paillier(phe)", "BFV(SEAL)"] {
            let he = pts.iter().find(|p| p.batch == *b && p.scheme == scheme).unwrap().stats.mean;
            let s = he / sa;
            min_speedup = min_speedup.min(s);
            max_speedup = max_speedup.max(s);
        }
    }
    println!("\nSA speedup over HE: {min_speedup:.1}x … {max_speedup:.1}x");
    println!("(paper reports 9.1e2 … 3.8e4 against un-vectorized Python HE;");
    println!(" our HE baselines are optimized Rust — see EXPERIMENTS.md E3)");
}
