//! Dropout-recovery demo (E7): the Bonawitz'17 extension the paper
//! cites as its robustness path (§5.1). A passive party goes offline
//! *after* the others have committed masks against it; t surviving
//! parties surrender Shamir shares of the dropped party's seed, the
//! aggregator reconstructs the dangling masks and the round completes.
//!
//!     cargo run --release --example dropout_recovery

use vfl::crypto::rng::DetRng;
use vfl::crypto::shamir::Share;
use vfl::secagg::dropout::{recover_dropped_mask, RobustClientSession, SeedShares};
use vfl::secagg::{FixedPoint, PublishedKeys};

fn main() {
    let n = 5usize; // 1 active + 4 passive
    let t = 3usize; // recovery threshold
    let dropped = 3usize;
    let len = 256 * 64; // one banking-sized activation
    let round = 2u64;
    let tag = 0u32;
    let mut rng = DetRng::from_seed(2024);

    println!("secure aggregation with dropout recovery (t={t} of n={n})\n");

    // --- setup phase: keys + seed shares ---
    let mut clients: Vec<RobustClientSession> =
        (0..n).map(|i| RobustClientSession::new(i, n, 0, t, &mut rng)).collect();
    let keys: Vec<PublishedKeys> = clients.iter().map(|c| c.inner.published_keys()).collect();
    for c in clients.iter_mut() {
        c.inner.derive_secrets(&keys);
    }
    let all_shares: Vec<SeedShares> = clients.iter().map(|c| c.share_seed(&mut rng)).collect();
    for s in &all_shares {
        for (j, bundle) in s.bundles.iter().enumerate() {
            clients[j].receive_share(s.owner, bundle.clone());
        }
    }
    println!("setup: {} clients exchanged keys and Shamir seed shares", n);

    // --- round: everyone except `dropped` submits masked activations ---
    let tensors: Vec<Vec<f32>> = (0..n).map(|i| vec![0.1 * (i as f32 + 1.0); len]).collect();
    let fp = FixedPoint::default();
    let mut acc = vec![0u64; len];
    for i in (0..n).filter(|&i| i != dropped) {
        let masked = clients[i].inner.mask_tensor(&tensors[i], round, tag);
        for (a, v) in acc.iter_mut().zip(&masked) {
            *a = a.wrapping_add(*v);
        }
    }
    println!("client {dropped} dropped after peers committed their masks");

    let want: f32 = (0..n).filter(|&i| i != dropped).map(|i| 0.1 * (i as f32 + 1.0)).sum();
    let garbage = fp.decode(acc[0]);
    println!("aggregate before recovery: {garbage:.3} (expected {want:.3}) — still masked ✗");
    assert!((garbage - want).abs() > 0.5);

    // --- recovery: t survivors surrender shares ---
    let surrendered: Vec<Vec<Share>> = (0..n)
        .filter(|&i| i != dropped)
        .take(t)
        .map(|i| clients[i].surrender_share(dropped).unwrap().clone())
        .collect();
    let t0 = std::time::Instant::now();
    let missing = recover_dropped_mask(dropped, n, 0, &surrendered, &keys, round, tag, len)
        .expect("recovery from t valid shares");
    for (a, m) in acc.iter_mut().zip(&missing) {
        *a = a.wrapping_add(*m);
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3;

    let fixed = fp.decode(acc[0]);
    println!("aggregate after recovery:  {fixed:.3} (expected {want:.3}) — unmasked ✓ [{ms:.1} ms]");
    assert!((fixed - want).abs() < 1e-3);

    // the dropped client's data never appeared in the clear
    let dropped_masked = clients[dropped].inner.mask_tensor(&tensors[dropped], round, tag);
    let leaked = fp.decode(dropped_masked[0]);
    println!("\ndropped client's own masked share decodes to {leaked:.3e} — never revealed");
    println!("recovery reconstructs only its *mask*, not its activation");

    // --- the same recovery, live inside the full training protocol ---
    use vfl::coordinator::{run_experiment, BackendKind, RunConfig, SecurityMode};
    use vfl::net::FaultPlan;
    println!("\nfull protocol run with the same fault (banking, 5 clients, t=3):");
    let mut cfg = RunConfig::test("banking").unwrap();
    cfg.security = SecurityMode::SecureExact;
    cfg.backend = BackendKind::Reference;
    cfg.train_rounds = 3;
    cfg.shamir_threshold = Some(t);
    cfg.fault_plan = Some(FaultPlan::crash_at(dropped, 1));
    let report = run_experiment(cfg, None).expect("round must recover");
    for (i, l) in report.losses.iter().enumerate() {
        println!("  round {i}: loss {l:.5}");
    }
    println!(
        "  test accuracy: {:.4} — the round completed without client {dropped}",
        report.test_accuracy
    );
}
