//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Runs the full secure VFL protocol (setup → 5 training rounds with
//! key rotation → testing) on the Banking configuration, twice: once
//! on the deterministic byte-metered simulation and once with every
//! party on its own OS thread. The same event-driven `Party` state
//! machines run in both cases — only the `Transport` changes — and the
//! two runs produce bit-identical losses and predictions.
//!
//! Uses the pure-Rust reference backend so it works before
//! `make artifacts`; pass `--pjrt` to run on the compiled artifacts
//! (requires a `--features pjrt` build).
//!
//!     cargo run --release --example quickstart [-- --pjrt]

use vfl::coordinator::{
    run_experiment, BackendKind, RunConfig, SecurityMode, TransportKind,
};
use vfl::model::ModelConfig;
use vfl::net::FaultPlan;
use vfl::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let pjrt = std::env::args().any(|a| a == "--pjrt");

    let mut cfg = RunConfig::paper("banking").unwrap();
    cfg.security = SecurityMode::SecureExact;
    cfg.backend = if pjrt { BackendKind::Pjrt } else { BackendKind::Reference };
    cfg.train_rounds = 5;
    cfg.test_rounds = 1;

    let engine = if pjrt {
        Some(Engine::load("artifacts", &ModelConfig::for_dataset("banking").unwrap())?)
    } else {
        None
    };

    println!("VFL + secure aggregation, banking dataset, 5 parties");
    println!("backend: {:?}\n", cfg.backend);

    // 1. the paper's measurement setup: single-threaded simulation
    //    over the byte-metered network
    cfg.transport = TransportKind::Sim;
    let sim = run_experiment(cfg.clone(), engine.as_ref())?;
    for (i, loss) in sim.losses.iter().enumerate() {
        println!("round {i}: loss {loss:.5}");
    }
    println!("\ntest accuracy: {:.4}", sim.test_accuracy);
    println!("setup phases run (1 initial + rotations): {}", sim.setups);

    // 2. the same parties, one OS thread each — identical results
    //    (reference backend only: a PJRT engine is not shared across
    //    party threads)
    if pjrt {
        println!("\n(threaded comparison skipped under --pjrt)");
        return Ok(());
    }
    cfg.transport = TransportKind::Threaded;
    let threaded = run_experiment(cfg.clone(), None)?;
    assert_eq!(sim.losses, threaded.losses, "transports must agree bit-for-bit");
    assert_eq!(sim.predictions, threaded.predictions);
    println!("\nthreaded transport reproduced the run bit-for-bit");

    // 3. dropout tolerance: Shamir-share mask seeds 3-of-5 at setup,
    //    crash a passive party at the start of round 1, and let the
    //    aggregator recover the round from surrendered shares
    cfg.transport = TransportKind::Sim;
    cfg.shamir_threshold = Some(3);
    cfg.fault_plan = Some(FaultPlan::crash_at(3, 1));
    let robust = run_experiment(cfg, None)?;
    assert!(robust.losses.iter().all(|l| l.is_finite()));
    println!("\ndropout-tolerant run (client 3 crashed in round 1):");
    for (i, loss) in robust.losses.iter().enumerate() {
        println!("round {i}: loss {loss:.5}");
    }
    println!("test accuracy: {:.4}", robust.test_accuracy);
    println!("(CLI: vfl-sa train --reference --shamir-threshold 3 --dropout-schedule 3@1)");
    println!("(for a multi-process run, see `vfl-sa serve` / `vfl-sa join`)");
    Ok(())
}
