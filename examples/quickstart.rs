//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Runs the full secure VFL protocol (setup → 5 training rounds with
//! key rotation → testing) on the Banking configuration and prints the
//! loss curve. Uses the pure-Rust reference backend so it works before
//! `make artifacts`; pass `--pjrt` to run on the compiled artifacts.
//!
//!     cargo run --release --example quickstart [-- --pjrt]

use vfl::coordinator::{run_experiment, BackendKind, RunConfig, SecurityMode};
use vfl::model::ModelConfig;
use vfl::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let pjrt = std::env::args().any(|a| a == "--pjrt");

    let mut cfg = RunConfig::paper("banking").unwrap();
    cfg.security = SecurityMode::SecureExact;
    cfg.backend = if pjrt { BackendKind::Pjrt } else { BackendKind::Reference };
    cfg.train_rounds = 5;
    cfg.test_rounds = 1;

    let engine = if pjrt {
        Some(Engine::load("artifacts", &ModelConfig::for_dataset("banking").unwrap())?)
    } else {
        None
    };

    println!("VFL + secure aggregation, banking dataset, 5 parties");
    println!("backend: {:?}\n", cfg.backend);
    let report = run_experiment(cfg, engine.as_ref())?;

    for (i, loss) in report.losses.iter().enumerate() {
        println!("round {i}: loss {loss:.5}");
    }
    println!("\ntest accuracy: {:.4}", report.test_accuracy);
    println!("setup phases run (1 initial + rotations): {}", report.setups);
    Ok(())
}
