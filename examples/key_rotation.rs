//! Key-rotation ablation (E6, §5.1): the paper argues keys should be
//! regenerated every K iterations to bound what a leaked key exposes,
//! at the cost of re-running the setup phase. This example sweeps K and
//! reports the overhead/traffic trade-off, plus the check that rotation
//! never changes the training outcome.
//!
//!     cargo run --release --example key_rotation

use vfl::coordinator::{run_experiment, BackendKind, RunConfig, SecurityMode};
use vfl::net::{Addr, Phase};

fn main() -> anyhow::Result<()> {
    println!("key-rotation period sweep (banking, 20 rounds, reference backend)\n");
    println!(
        "{:<10} {:>8} {:>16} {:>16} {:>14} {:>12}",
        "K", "setups", "active_ovh_ms", "active_setup_B", "final_loss", "accuracy"
    );

    let mut baseline_losses: Option<Vec<f32>> = None;
    for k in [1usize, 5, 10, 20] {
        let mut cfg = RunConfig::paper("banking").unwrap();
        cfg.backend = BackendKind::Reference;
        cfg.security = SecurityMode::SecureExact;
        cfg.train_rounds = 20;
        cfg.test_rounds = 1;
        cfg.model.rotation_period = k;
        let report = run_experiment(cfg, None)?;
        println!(
            "{:<10} {:>8} {:>16.2} {:>16} {:>14.5} {:>12.4}",
            k,
            report.setups,
            report.metrics.overhead_ms(1, Phase::Training)
                + report.metrics.overhead_ms(1, Phase::Setup),
            report.net.transmission_bytes(Addr::Client(0), Phase::Setup)
                + report.net.transmission_bytes(Addr::Client(0), Phase::Training),
            report.losses.last().unwrap(),
            report.test_accuracy,
        );
        match &baseline_losses {
            None => baseline_losses = Some(report.losses.clone()),
            Some(base) => {
                let max_diff = base
                    .iter()
                    .zip(&report.losses)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(max_diff < 1e-3, "rotation period must not change training (diff {max_diff})");
            }
        }
    }
    println!("\n→ smaller K = more setup traffic/CPU, identical training trajectory");
    println!("  (the paper's security argument: leaked keys expose at most K rounds)");
    Ok(())
}
